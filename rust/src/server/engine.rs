//! The generation engine: continuous batching over a compute backend.
//!
//! Scheduling model (vLLM-style, specialized to this testbed): a FIFO
//! waiting queue; up to `max_batch` active requests; each scheduler round
//! advances every active request by one decode step (prefill first,
//! token by token, dense per the paper's Setup B); completed requests
//! free their slot immediately and the queue backfills.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::{Request, RequestResult};
use crate::attention::Selection;
use crate::kvcache::KvCache;
use crate::model::{Model, ModelConfig, Sampler, StepOut};
use crate::policies::{IndexPolicy, PolicyCtx};
use crate::tensor::Mat;
use crate::util::Rng;

/// Compute backend abstraction: the rust-native model or the PJRT path.
pub trait Backend {
    fn config(&self) -> &ModelConfig;
    fn step(
        &self,
        token: u32,
        pos: usize,
        cache: &mut KvCache,
        select: Option<&mut dyn FnMut(usize, usize, &Mat, &Mat, &[f32]) -> Selection>,
    ) -> Result<StepOut>;
}

impl Backend for Model {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }
    fn step(
        &self,
        token: u32,
        pos: usize,
        cache: &mut KvCache,
        select: Option<&mut dyn FnMut(usize, usize, &Mat, &Mat, &[f32]) -> Selection>,
    ) -> Result<StepOut> {
        Ok(self.decode_step(token, pos, cache, select))
    }
}

impl Backend for crate::runtime::PjrtModel {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }
    fn step(
        &self,
        token: u32,
        pos: usize,
        cache: &mut KvCache,
        select: Option<&mut dyn FnMut(usize, usize, &Mat, &Mat, &[f32]) -> Selection>,
    ) -> Result<StepOut> {
        self.decode_step(token, pos, cache, select)
    }
}

/// Creates a fresh policy per (layer, head) for each admitted request.
pub type PolicyFactory = Box<dyn Fn(usize, usize) -> Box<dyn IndexPolicy>>;

/// How decode attention is computed.
pub enum AttentionMode {
    Dense,
    Sparse(PolicyFactory),
}

pub struct EngineConfig {
    pub max_batch: usize,
    pub sampler: Sampler,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { max_batch: 4, sampler: Sampler::Greedy, seed: 0 }
    }
}

/// One active request's serving state.
struct Active {
    req: Request,
    cache: KvCache,
    policies: Vec<Box<dyn IndexPolicy>>, // L*H, empty in dense mode
    rng: Rng,
    tokens: Vec<u32>,
    next_token: u32,
    pos: usize,
    prefill_left: usize,
    started: Instant,
    ttft_s: f64,
    decode_s: f64,
    density_sum: f64,
    density_n: usize,
    step: usize,
}

pub struct Engine<B: Backend> {
    pub backend: B,
    pub cfg: EngineConfig,
}

impl<B: Backend> Engine<B> {
    pub fn new(backend: B, cfg: EngineConfig) -> Engine<B> {
        Engine { backend, cfg }
    }

    /// Serve a batch of requests to completion with continuous batching.
    pub fn serve(&self, requests: Vec<Request>, mode: &AttentionMode) -> Result<Vec<RequestResult>> {
        let mcfg = self.backend.config().clone();
        let mut waiting: VecDeque<Request> = requests.into();
        let mut active: Vec<Active> = Vec::new();
        let mut done: Vec<RequestResult> = Vec::new();
        let mut seed_rng = Rng::new(self.cfg.seed);

        loop {
            // ── admission: backfill free slots FIFO ──
            while active.len() < self.cfg.max_batch {
                let Some(req) = waiting.pop_front() else { break };
                let policies = match mode {
                    AttentionMode::Dense => Vec::new(),
                    AttentionMode::Sparse(factory) => {
                        let mut v = Vec::with_capacity(mcfg.n_layers * mcfg.n_heads);
                        for l in 0..mcfg.n_layers {
                            for h in 0..mcfg.n_heads {
                                v.push(factory(l, h));
                            }
                        }
                        v
                    }
                };
                let first = *req.prompt.first().unwrap_or(&0);
                active.push(Active {
                    prefill_left: req.prompt.len(),
                    cache: KvCache::new(&mcfg),
                    policies,
                    rng: seed_rng.fork(req.id),
                    tokens: Vec::new(),
                    next_token: first,
                    pos: 0,
                    started: Instant::now(),
                    ttft_s: 0.0,
                    decode_s: 0.0,
                    density_sum: 0.0,
                    density_n: 0,
                    step: 0,
                    req,
                });
            }
            if active.is_empty() {
                break;
            }

            // ── one scheduler round: each active request advances a step ──
            let mut i = 0;
            while i < active.len() {
                let a = &mut active[i];
                let t0 = Instant::now();
                let out = if a.prefill_left > 0 {
                    // Prefill (dense, Setup B: context via full attention).
                    let tok = a.req.prompt[a.pos];
                    let out = self.backend.step(tok, a.pos, &mut a.cache, None)?;
                    a.prefill_left -= 1;
                    a.pos += 1;
                    if a.prefill_left == 0 {
                        a.ttft_s = a.started.elapsed().as_secs_f64();
                        a.cache.stats.reset(); // count decode traffic only
                    }
                    out
                } else {
                    // Decode (sparse per policy).
                    let n_heads = mcfg.n_heads;
                    let sparse = !a.policies.is_empty();
                    let policies = &mut a.policies;
                    let rng = &mut a.rng;
                    let step = a.step;
                    let mut select = |l: usize, h: usize, k: &Mat, v: &Mat, q: &[f32]| {
                        let mut ctx = PolicyCtx { k, v, q_scaled: q, rng, step };
                        policies[l * n_heads + h].select(&mut ctx)
                    };
                    let sel_opt: Option<&mut dyn FnMut(usize, usize, &Mat, &Mat, &[f32]) -> Selection> =
                        if sparse { Some(&mut select) } else { None };
                    let out = self.backend.step(a.next_token, a.pos, &mut a.cache, sel_opt)?;
                    a.decode_s += t0.elapsed().as_secs_f64();
                    a.pos += 1;
                    a.step += 1;
                    a.density_sum += out.mean_density;
                    a.density_n += 1;
                    out
                };
                // Sample the next token once the prompt is fully ingested.
                if a.prefill_left == 0 {
                    let tok = self.cfg.sampler.sample(&out.logits, &mut a.rng);
                    if a.tokens.len() < a.req.gen_len {
                        // The token just generated becomes the next input.
                        if a.step > 0 || a.pos == a.req.prompt.len() {
                            a.tokens.push(tok);
                            a.next_token = tok;
                        }
                    }
                }
                // ── completion ──
                if a.prefill_left == 0 && a.tokens.len() >= a.req.gen_len {
                    let a = active.swap_remove(i);
                    done.push(RequestResult {
                        id: a.req.id,
                        tokens: a.tokens,
                        ttft_s: a.ttft_s,
                        decode_s: a.decode_s,
                        mean_density: if a.density_n > 0 {
                            a.density_sum / a.density_n as f64
                        } else {
                            1.0
                        },
                        kv_bytes_read: a.cache.stats.bytes_read,
                    });
                    continue; // don't advance i: swapped element takes slot
                }
                i += 1;
            }
        }
        done.sort_by_key(|r| r.id);
        Ok(done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::{SizeSpec, VAttentionConfig, VAttentionPolicy};

    fn tiny_engine() -> Engine<Model> {
        let cfg = ModelConfig::tiny();
        Engine::new(Model::new(cfg, 42), EngineConfig::default())
    }

    fn reqs(n: usize, prompt_len: usize, gen_len: usize) -> Vec<Request> {
        (0..n as u64)
            .map(|i| {
                let prompt: Vec<u32> = (0..prompt_len as u32).map(|t| (i as u32 * 7 + t) % 250).collect();
                Request::new(i, prompt, gen_len)
            })
            .collect()
    }

    #[test]
    fn serves_all_requests_dense() {
        let eng = tiny_engine();
        let results = eng.serve(reqs(6, 12, 5), &AttentionMode::Dense).unwrap();
        assert_eq!(results.len(), 6);
        for r in &results {
            assert_eq!(r.tokens.len(), 5);
            assert!((r.mean_density - 1.0).abs() < 1e-9);
            assert!(r.ttft_s >= 0.0);
        }
        // FIFO ids preserved in output ordering
        let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn greedy_dense_is_deterministic() {
        let eng = tiny_engine();
        let a = eng.serve(reqs(2, 10, 6), &AttentionMode::Dense).unwrap();
        let b = eng.serve(reqs(2, 10, 6), &AttentionMode::Dense).unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn sparse_mode_reads_fewer_bytes() {
        let eng = tiny_engine();
        let mk_mode = || -> AttentionMode {
            AttentionMode::Sparse(Box::new(|_l, _h| {
                let mut cfg = VAttentionConfig::default();
                cfg.sink = SizeSpec::Abs(4);
                cfg.window = SizeSpec::Abs(8);
                cfg.heavy = SizeSpec::Frac(0.05);
                // Random-weight tiny models have unstructured values, so
                // the full-SDPA guarantee correctly saturates at dense —
                // use the denominator guarantee at a moderate tolerance
                // to exercise genuine sparsity here (cf. Fig. 10).
                cfg.verify = crate::budget::Verify::Denominator;
                cfg.eps = 0.2;
                cfg.delta = 0.2;
                Box::new(VAttentionPolicy::oracle(cfg))
            }))
        };
        // Long prompt so sparsity has room.
        let dense = eng.serve(reqs(1, 192, 8), &AttentionMode::Dense).unwrap();
        let sparse = eng.serve(reqs(1, 192, 8), &mk_mode()).unwrap();
        assert!(sparse[0].mean_density < 1.0);
        assert!(sparse[0].kv_bytes_read < dense[0].kv_bytes_read);
        assert_eq!(sparse[0].tokens.len(), 8);
    }

    #[test]
    fn batch_capacity_respected_and_all_complete() {
        let eng = Engine::new(
            Model::new(ModelConfig::tiny(), 1),
            EngineConfig { max_batch: 2, ..Default::default() },
        );
        let results = eng.serve(reqs(7, 6, 3), &AttentionMode::Dense).unwrap();
        assert_eq!(results.len(), 7);
        assert!(results.iter().all(|r| r.tokens.len() == 3));
    }

    #[test]
    fn empty_request_list_ok() {
        let eng = tiny_engine();
        assert!(eng.serve(vec![], &AttentionMode::Dense).unwrap().is_empty());
    }
}
