//! Latency extrapolation to the paper's 8B-scale shapes (Fig. 5).
//!
//! Decode at long context is memory-bound: per-token latency is
//! dominated by reading the KV cache. With a host-resident cache a
//! sparse policy reads `density × n` tokens, so latency scales
//! near-linearly with density — the Fig. 5 claim. We combine measured
//! per-token read throughput on *this* machine (from the benches) with
//! the analytic model below for the 8B shapes we cannot materialize.

use crate::kvcache::TransferModel;
use crate::model::ModelConfig;

/// Decode latency model for one token at context length `n` and
/// attention density `rho`.
#[derive(Clone, Debug)]
pub struct DecodeLatencyModel {
    pub cfg: ModelConfig,
    /// Link the gathered KV rows traverse (host→device).
    pub link: TransferModel,
    /// Fixed non-attention compute+overhead per token, seconds.
    pub fixed_s: f64,
    /// Per-token index-computation overhead as a fraction of the dense
    /// read time (vAttention's selection pass scans scores, not values).
    pub index_overhead_frac: f64,
}

impl DecodeLatencyModel {
    /// Defaults matching the paper's CPU-offload deployment of
    /// Llama-class models over PCIe-4-ish links.
    pub fn for_model(cfg: ModelConfig) -> DecodeLatencyModel {
        DecodeLatencyModel {
            cfg,
            link: TransferModel::default(),
            fixed_s: 4e-3,
            index_overhead_frac: 0.04,
        }
    }

    /// KV bytes one decode step reads at density `rho` (f16 as deployed;
    /// GQA-aware: only n_kv_heads × d_head per K/V per layer).
    pub fn kv_bytes(&self, n: usize, rho: f64) -> f64 {
        let kv_dim = (self.cfg.n_kv_heads * self.cfg.d_head()) as f64;
        let per_token = 2.0 * kv_dim * 2.0 * self.cfg.n_layers as f64;
        per_token * n as f64 * rho
    }

    /// Modeled per-token decode latency (seconds).
    pub fn latency(&self, n: usize, rho: f64) -> f64 {
        let read = self.link.transfer_time(self.kv_bytes(n, rho) as usize, self.cfg.n_layers);
        let index = self.index_overhead_frac * self.link.transfer_time(self.kv_bytes(n, 1.0) as usize, 0)
            * if rho < 1.0 { 1.0 } else { 0.0 };
        self.fixed_s + read + index
    }

    /// Speedup of density `rho` over dense.
    pub fn speedup(&self, n: usize, rho: f64) -> f64 {
        self.latency(n, 1.0) / self.latency(n, rho)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> DecodeLatencyModel {
        DecodeLatencyModel::for_model(ModelConfig::llama8b_shape())
    }

    #[test]
    fn dense_latency_grows_with_context() {
        let m = model();
        assert!(m.latency(131_072, 1.0) > m.latency(8_192, 1.0) * 4.0);
    }

    #[test]
    fn speedup_near_linear_at_long_context() {
        // At 128K context the fixed cost is negligible, so 10% density
        // should give ≥ ~5× speedup (paper reports near-linear).
        let m = model();
        let s = m.speedup(131_072, 0.1);
        assert!(s > 5.0 && s < 11.0, "speedup={s}");
    }

    #[test]
    fn speedup_saturates_at_short_context() {
        // Fixed costs bite at short context: speedup must be clearly
        // below the long-context value (Fig. 5's flattening on the left).
        let m = model();
        let short = m.speedup(1024, 0.1);
        let long = m.speedup(131_072, 0.1);
        assert!(short > 1.0 && short < 0.6 * long, "short={short} long={long}");
    }

    #[test]
    fn kv_bytes_match_shape_math() {
        let m = model();
        // llama8b GQA shape at f16: 2*8*128*2*32 = 128 KiB per token.
        assert!((m.kv_bytes(1, 1.0) - 131_072.0).abs() < 1.0);
    }
}
