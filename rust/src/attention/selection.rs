//! The `Selection` type: a sequence of KV indices with their selection
//! probabilities (Eq. 3 of the paper). Deterministic picks carry p = 1;
//! uniformly sampled residual picks carry p = b / n_s.

/// A set of selected KV indices and the probability each index was
/// selected with. Invariants (checked by `validate`):
///   * indices are unique and in-range,
///   * probabilities are in (0, 1].
#[derive(Clone, Debug, Default)]
pub struct Selection {
    pub idx: Vec<usize>,
    pub prob: Vec<f32>,
}

impl Selection {
    /// All-deterministic selection (p = 1 everywhere). Subsumes Eq. 2.
    pub fn deterministic(idx: Vec<usize>) -> Selection {
        let prob = vec![1.0; idx.len()];
        Selection { idx, prob }
    }

    /// A uniformly-sampled selection where every index was drawn with the
    /// same probability `p`.
    pub fn sampled(idx: Vec<usize>, p: f32) -> Selection {
        let prob = vec![p; idx.len()];
        Selection { idx, prob }
    }

    /// Concatenate deterministic indices (p = 1) with sampled indices
    /// (p = `p_dyn` each) — the composition of Algorithm 1, lines 9–10.
    pub fn compose(deterministic: Vec<usize>, sampled: Vec<usize>, p_dyn: f32) -> Selection {
        let mut idx = deterministic;
        let n_det = idx.len();
        idx.extend_from_slice(&sampled);
        let mut prob = vec![1.0f32; n_det];
        prob.resize(idx.len(), p_dyn);
        Selection { idx, prob }
    }

    /// Per-index probabilities (e.g. MagicPig's LSH collision probs).
    pub fn with_probs(idx: Vec<usize>, prob: Vec<f32>) -> Selection {
        assert_eq!(idx.len(), prob.len());
        Selection { idx, prob }
    }

    pub fn len(&self) -> usize {
        self.idx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    /// Fraction of the cache this selection touches.
    pub fn density(&self, n: usize) -> f64 {
        if n == 0 {
            0.0
        } else {
            self.idx.len() as f64 / n as f64
        }
    }

    /// Check the structural invariants against a cache of size `n`.
    pub fn validate(&self, n: usize) -> Result<(), String> {
        if self.idx.len() != self.prob.len() {
            return Err(format!(
                "idx/prob length mismatch: {} vs {}",
                self.idx.len(),
                self.prob.len()
            ));
        }
        let mut seen = vec![false; n];
        for (&i, &p) in self.idx.iter().zip(self.prob.iter()) {
            if i >= n {
                return Err(format!("index {i} out of range (n={n})"));
            }
            if seen[i] {
                return Err(format!("duplicate index {i}"));
            }
            seen[i] = true;
            if !(p > 0.0 && p <= 1.0) {
                return Err(format!("probability {p} for index {i} outside (0,1]"));
            }
        }
        Ok(())
    }

    /// Truncate to at most `budget` entries, keeping the first entries
    /// (deterministic ones come first by construction).
    pub fn truncate(&mut self, budget: usize) {
        self.idx.truncate(budget);
        self.prob.truncate(budget);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compose_layout() {
        let s = Selection::compose(vec![0, 1, 2], vec![10, 20], 0.25);
        assert_eq!(s.idx, vec![0, 1, 2, 10, 20]);
        assert_eq!(s.prob, vec![1.0, 1.0, 1.0, 0.25, 0.25]);
        assert!(s.validate(32).is_ok());
    }

    #[test]
    fn validate_catches_out_of_range() {
        let s = Selection::deterministic(vec![5]);
        assert!(s.validate(5).is_err());
        assert!(s.validate(6).is_ok());
    }

    #[test]
    fn validate_catches_duplicates() {
        let s = Selection::deterministic(vec![1, 2, 1]);
        assert!(s.validate(10).is_err());
    }

    #[test]
    fn validate_catches_bad_probs() {
        let s = Selection::with_probs(vec![0, 1], vec![0.5, 0.0]);
        assert!(s.validate(10).is_err());
        let s = Selection::with_probs(vec![0, 1], vec![0.5, 1.5]);
        assert!(s.validate(10).is_err());
    }

    #[test]
    fn density() {
        let s = Selection::deterministic(vec![0, 1, 2, 3]);
        assert!((s.density(16) - 0.25).abs() < 1e-12);
        assert_eq!(Selection::default().density(0), 0.0);
    }

    #[test]
    fn truncate_keeps_prefix() {
        let mut s = Selection::compose(vec![0, 1], vec![5, 6], 0.5);
        s.truncate(3);
        assert_eq!(s.idx, vec![0, 1, 5]);
        assert_eq!(s.prob.len(), 3);
    }
}
