//! Attention computations from §3 of the paper:
//!
//! * Eq. 1 — full SDPA over the whole KV cache (the oracle everything is
//!   measured against);
//! * Eq. 2 — sparse attention over a deterministic index set (renormalized
//!   softmax over the subset);
//! * Eq. 3 — sparse attention with randomized index selection and
//!   importance weights `1/p_i` (subsumes Eq. 2 when all p_i = 1).
//!
//! All computations are max-logit stabilized. Attention ratios are
//! invariant to a shared logit shift, so every N/D pair in the repo is
//! expressed relative to a caller-chosen reference logit `m_ref`.

pub mod selection;

pub use selection::Selection;

use std::sync::OnceLock;

use crate::tensor::{dot, Mat};
use crate::util::threadpool::ThreadPool;

/// Raw query–key logits `⟨K[i], q·scale⟩` for all i. `scale` is typically
/// 1/√d (callers pre-scale q once instead of scaling every logit).
pub fn logits_all(k: &Mat, q_scaled: &[f32]) -> Vec<f32> {
    (0..k.rows).map(|i| dot(k.row(i), q_scaled)).collect()
}

/// Logits for a subset of rows.
pub fn logits_for(k: &Mat, q_scaled: &[f32], idx: &[usize]) -> Vec<f32> {
    idx.iter().map(|&i| dot(k.row(i), q_scaled)).collect()
}

/// Output of a full-attention computation plus the stabilized pieces the
/// budget machinery wants to reuse.
#[derive(Clone, Debug)]
pub struct DenseOut {
    /// Attention output Σ a_i v_i (length d).
    pub out: Vec<f32>,
    /// Max logit used for stabilization.
    pub m: f32,
    /// Stabilized denominator D = Σ exp(l_i - m).
    pub denom: f64,
}

/// Row-count threshold above which dense SDPA fans out across threads
/// (flash-style chunk merge). Below it, threading overhead dominates.
const PARALLEL_THRESHOLD: usize = 16_384;

/// Eq. 1: full SDPA for a single head/query.
///
/// Large caches are processed in parallel row chunks, each keeping a
/// stabilized (m, denom, acc) triple, merged with the standard
/// flash-attention rescaling — bitwise order-independent up to f32
/// rounding. This takes the 32K-row scan from single-core DRAM bandwidth
/// to multi-channel bandwidth (EXPERIMENTS.md §Perf iteration 3).
pub fn dense_sdpa(k: &Mat, v: &Mat, q_scaled: &[f32]) -> DenseOut {
    if k.rows >= PARALLEL_THRESHOLD {
        return dense_sdpa_parallel(k, v, q_scaled);
    }
    dense_sdpa_chunk(k, v, q_scaled, 0, k.rows)
}

/// Single-threaded SDPA over rows [lo, hi). Logits are buffered so K is
/// scanned exactly once (recomputing the dot in the weight pass costs
/// ~1.5× — measured in §Perf iteration 3a).
fn dense_sdpa_chunk(k: &Mat, v: &Mat, q_scaled: &[f32], lo: usize, hi: usize) -> DenseOut {
    let d = v.cols;
    // Arena-recycled logit scratch: this runs once per decode step per
    // request, and the buffer's contents never leave the function, so
    // reuse cannot affect results (util::arena module docs).
    let mut logits = crate::util::arena::take_f32();
    logits.reserve(hi - lo);
    let mut m = f32::NEG_INFINITY;
    for i in lo..hi {
        let l = dot(k.row(i), q_scaled);
        if l > m {
            m = l;
        }
        logits.push(l);
    }
    let mut out = vec![0.0f32; d];
    let mut denom = 0.0f64;
    for (j, &l) in logits.iter().enumerate() {
        let w = (l - m).exp();
        denom += w as f64;
        crate::tensor::axpy(w, v.row(lo + j), &mut out);
    }
    crate::util::arena::recycle_f32(logits);
    let inv = (1.0 / denom) as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
    DenseOut { out, m, denom }
}

/// Shared worker pool for chunked dense SDPA, initialized on first use
/// and reused for every large-cache query thereafter — the per-call
/// `std::thread::scope` spawn this replaces cost a thread create/join
/// per worker per query, pure overhead at decode rates. Deliberately a
/// *separate* pool from the serving engine's: SDPA runs inside engine
/// worker threads, and nesting blocking waits inside one fixed-size
/// pool can deadlock.
fn sdpa_pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| {
        ThreadPool::new(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8))
    })
}

/// Parallel chunked SDPA with flash-merge, fanned out over the shared
/// `sdpa_pool` workers (scoped: chunks borrow K/V/q directly).
fn dense_sdpa_parallel(k: &Mat, v: &Mat, q_scaled: &[f32]) -> DenseOut {
    let pool = sdpa_pool();
    let threads = pool.num_workers();
    let n = k.rows;
    let chunk = n.div_ceil(threads);
    let parts: Vec<DenseOut> = pool
        .scoped_map(threads, |t| {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo < hi {
                Some(dense_sdpa_chunk(k, v, q_scaled, lo, hi))
            } else {
                None
            }
        })
        .into_iter()
        .flatten()
        .collect();
    // Merge: rescale every chunk's (denom, out·denom) to the global max.
    let m = parts.iter().fold(f32::NEG_INFINITY, |a, p| a.max(p.m));
    let d = v.cols;
    let mut out = vec![0.0f32; d];
    let mut denom = 0.0f64;
    for p in &parts {
        let scale = ((p.m - m) as f64).exp();
        denom += p.denom * scale;
        // p.out is already normalized by p.denom; un-normalize + rescale.
        let w = (p.denom * scale) as f32;
        crate::tensor::axpy(w, &p.out, &mut out);
    }
    let inv = (1.0 / denom) as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
    DenseOut { out, m, denom }
}

/// Eq. 2 / Eq. 3: sparse SDPA over `sel` with importance weights. Each
/// selected index i contributes (1/p_i)·exp(l_i - m) where m is the max
/// logit *within the selection* (self-stabilizing; the ratio N/D is
/// shift-invariant so this matches the unstabilized Eq. 3 exactly in
/// exact arithmetic).
pub fn sparse_sdpa(k: &Mat, v: &Mat, q_scaled: &[f32], sel: &Selection) -> Vec<f32> {
    let d = v.cols;
    if sel.idx.is_empty() {
        return vec![0.0; d];
    }
    // Arena-recycled logit scratch (see dense_sdpa_chunk).
    let mut logits = crate::util::arena::take_f32();
    logits.extend(sel.idx.iter().map(|&i| dot(k.row(i), q_scaled)));
    // Stabilize including the log-importance weights, since the weighted
    // exponent is what actually enters the sum.
    let mut m = f32::NEG_INFINITY;
    for (j, &l) in logits.iter().enumerate() {
        let lw = l - sel.prob[j].ln();
        if lw > m {
            m = lw;
        }
    }
    let mut out = vec![0.0f32; d];
    let mut denom = 0.0f64;
    for (j, &l) in logits.iter().enumerate() {
        let w = (l - sel.prob[j].ln() - m).exp();
        denom += w as f64;
        crate::tensor::axpy(w, v.row(sel.idx[j]), &mut out);
    }
    crate::util::arena::recycle_f32(logits);
    let inv = (1.0 / denom) as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
    out
}

/// Stabilized numerator/denominator of the vAttention estimator (Eqs. 6–7)
/// relative to a caller-supplied reference logit `m_ref`:
///   N̂ = Σ_i (1/p_i) exp(l_i - m_ref) v_i,  D̂ = Σ_i (1/p_i) exp(l_i - m_ref).
/// Exposed for the budget machinery and for verified-N / verified-D
/// experiments that need the raw estimates, not just the ratio.
pub fn weighted_num_den(
    k: &Mat,
    v: &Mat,
    q_scaled: &[f32],
    sel: &Selection,
    m_ref: f32,
) -> (Vec<f32>, f64) {
    let d = v.cols;
    let mut num = vec![0.0f32; d];
    let mut den = 0.0f64;
    for (j, &i) in sel.idx.iter().enumerate() {
        let l = dot(k.row(i), q_scaled);
        let w = ((l - m_ref).exp() as f64 / sel.prob[j] as f64) as f32;
        den += w as f64;
        crate::tensor::axpy(w, v.row(i), &mut num);
    }
    (num, den)
}

/// Exact (dense) stabilized numerator/denominator relative to `m_ref`.
pub fn exact_num_den(k: &Mat, v: &Mat, q_scaled: &[f32], m_ref: f32) -> (Vec<f32>, f64) {
    let d = v.cols;
    let mut num = vec![0.0f32; d];
    let mut den = 0.0f64;
    for i in 0..k.rows {
        let l = dot(k.row(i), q_scaled);
        let w = (l - m_ref).exp();
        den += w as f64;
        crate::tensor::axpy(w, v.row(i), &mut num);
    }
    (num, den)
}

/// Full attention scores a_i (softmax over all logits). Used by oracle
/// policies (top-k / top-p / H2O) and the coverage plots of Fig. 2.
pub fn attention_scores(k: &Mat, q_scaled: &[f32]) -> Vec<f32> {
    let mut l = logits_all(k, q_scaled);
    crate::tensor::softmax_inplace(&mut l);
    l
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn toy(n: usize, d: usize, seed: u64) -> (Mat, Mat, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let k = Mat::randn(n, d, 1.0, &mut rng);
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let scale = 1.0 / (d as f32).sqrt();
        let q: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0) * scale).collect();
        (k, v, q)
    }

    #[test]
    fn dense_matches_naive() {
        let (k, v, q) = toy(50, 8, 1);
        let got = dense_sdpa(&k, &v, &q);
        // naive f64 reference
        let logits: Vec<f64> = (0..50).map(|i| dot(k.row(i), &q) as f64).collect();
        let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let ws: Vec<f64> = logits.iter().map(|l| (l - mx).exp()).collect();
        let wsum: f64 = ws.iter().sum();
        for c in 0..8 {
            let want: f64 =
                (0..50).map(|i| ws[i] / wsum * v.get(i, c) as f64).sum();
            assert!((got.out[c] as f64 - want).abs() < 1e-5);
        }
    }

    #[test]
    fn full_selection_equals_dense() {
        let (k, v, q) = toy(64, 16, 2);
        let sel = Selection::deterministic((0..64).collect());
        let sparse = sparse_sdpa(&k, &v, &q, &sel);
        let dense = dense_sdpa(&k, &v, &q).out;
        let err = crate::tensor::rel_l2_error(&sparse, &dense);
        assert!(err < 1e-6, "err={err}");
    }

    #[test]
    fn subset_renormalizes() {
        let (k, v, q) = toy(20, 4, 3);
        let sel = Selection::deterministic(vec![0, 5, 7]);
        let out = sparse_sdpa(&k, &v, &q, &sel);
        // manual Eq. 2
        let l = logits_for(&k, &q, &[0, 5, 7]);
        let mx = l.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let w: Vec<f32> = l.iter().map(|x| (x - mx).exp()).collect();
        let s: f32 = w.iter().sum();
        for c in 0..4 {
            let want = (w[0] * v.get(0, c) + w[1] * v.get(5, c) + w[2] * v.get(7, c)) / s;
            assert!((out[c] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn importance_weights_are_unbiased_for_denominator() {
        // Sampling half the tokens with p=1/2 should give an unbiased D̂:
        // average over many resamples converges to exact D.
        let (k, v, q) = toy(200, 8, 4);
        let m_ref = 0.0f32;
        let (_, d_exact) = exact_num_den(&k, &v, &q, m_ref);
        let mut rng = Rng::new(99);
        let trials = 3000;
        let mut acc = 0.0f64;
        for _ in 0..trials {
            let idx = rng.sample_distinct(200, 100);
            let sel = Selection::sampled(idx, 100.0 / 200.0);
            let (_, d_hat) = weighted_num_den(&k, &v, &q, &sel, m_ref);
            acc += d_hat;
        }
        let mean = acc / trials as f64;
        let rel = (mean - d_exact).abs() / d_exact;
        assert!(rel < 0.01, "bias rel={rel}");
    }

    #[test]
    fn attention_scores_sum_to_one_and_rank_correctly() {
        let (k, _, q) = toy(30, 8, 5);
        let a = attention_scores(&k, &q);
        let s: f32 = a.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        // highest logit gets highest score
        let l = logits_all(&k, &q);
        let arg_l = (0..30).max_by(|&a_, &b_| l[a_].partial_cmp(&l[b_]).unwrap()).unwrap();
        let arg_a = (0..30).max_by(|&x, &y| a[x].partial_cmp(&a[y]).unwrap()).unwrap();
        assert_eq!(arg_l, arg_a);
    }

    #[test]
    fn empty_selection_returns_zero() {
        let (k, v, q) = toy(10, 4, 6);
        let sel = Selection::deterministic(vec![]);
        assert_eq!(sparse_sdpa(&k, &v, &q, &sel), vec![0.0; 4]);
    }

    #[test]
    fn stabilization_handles_huge_logits() {
        // Keys scaled so raw exp would overflow f32.
        let mut rng = Rng::new(7);
        let k = Mat::randn(16, 8, 40.0, &mut rng);
        let v = Mat::randn(16, 8, 1.0, &mut rng);
        let q: Vec<f32> = (0..8).map(|_| rng.normal32(0.0, 4.0)).collect();
        let out = dense_sdpa(&k, &v, &q);
        assert!(out.out.iter().all(|x| x.is_finite()));
        let sel = Selection::deterministic((0..16).collect());
        let sp = sparse_sdpa(&k, &v, &q, &sel);
        assert!(crate::tensor::rel_l2_error(&sp, &out.out) < 1e-5);
    }

    #[test]
    fn parallel_dense_matches_serial() {
        // Above the threading threshold, results must agree with the
        // single-threaded chunk implementation to f32 tolerance.
        let (k, v, q) = toy(20_000, 16, 9);
        let par = dense_sdpa(&k, &v, &q);
        let ser = dense_sdpa_chunk(&k, &v, &q, 0, 20_000);
        let err = crate::tensor::rel_l2_error(&par.out, &ser.out);
        assert!(err < 1e-5, "parallel vs serial err {err}");
        assert!((par.denom / ser.denom - 1.0).abs() < 1e-5);
    }

    #[test]
    fn parallel_dense_reuses_the_shared_pool_across_calls() {
        // Back-to-back large queries ride the same lazily-initialized
        // worker pool (no spawn per call) and stay deterministic.
        let (k, v, q) = toy(17_000, 16, 10);
        let a = dense_sdpa(&k, &v, &q);
        let b = dense_sdpa(&k, &v, &q);
        assert_eq!(a.out, b.out, "repeated pooled runs must be bitwise identical");
        assert_eq!(a.denom, b.denom);
    }
}
