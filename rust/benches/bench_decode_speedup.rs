//! Fig. 5 end-to-end bench: full decode step (selection + gather +
//! weighted attention) at several densities over long host-resident
//! caches, per-layer-slice at Llama-8B head shape; reports the measured
//! speedup curve that EXPERIMENTS.md compares against the paper's.
//!
//! Run: cargo bench --bench bench_decode_speedup

use std::time::Duration;

use vattn::attention::{dense_sdpa, sparse_sdpa};
use vattn::policies::{IndexPolicy, PolicyCtx, VAttentionPolicy};
use vattn::util::timer::bench;
use vattn::util::Rng;
use vattn::workloads::{synthesize_head, ScoreProfile};

fn main() {
    let budget = Duration::from_millis(500);
    let mut rng = Rng::new(42);
    let d = 128; // llama-8b head dim

    println!("== Fig 5: decode hot path at llama head shape (d=128) ==");
    for &n in &[16_384usize, 65_536, 131_072] {
        let head = synthesize_head(n, d, ScoreProfile::Mixed { heavy: 16, boost: 6.0, alpha: 0.9 }, &mut rng);
        let s_dense = bench(&format!("dense decode n={n}"), 1, budget, 3, || {
            dense_sdpa(&head.k, &head.v, &head.q_scaled)
        });
        println!("{}", s_dense.report());

        for eps in [0.05f64, 0.1, 0.2] {
            let mut cfg = vattn::experiments::common::vcfg(eps);
            cfg.floor_at_base = false;
            let mut pol = VAttentionPolicy::oracle(cfg);
            let mut fork = rng.fork(n as u64 ^ (eps * 1000.0) as u64);
            let mut density = 0.0f64;
            let mut iters = 0usize;
            let s = bench(&format!("vattention decode n={n} eps={eps}"), 1, budget, 3, || {
                let mut ctx = PolicyCtx { k: &head.k, v: &head.v, q_scaled: &head.q_scaled, rng: &mut fork, step: 0 };
                let sel = pol.select(&mut ctx);
                density += sel.density(n);
                iters += 1;
                sparse_sdpa(&head.k, &head.v, &head.q_scaled, &sel)
            });
            println!(
                "{}   density {:.3}  speedup {:.2}x",
                s.report(),
                density / iters as f64,
                s_dense.p50_s / s.p50_s
            );
        }
        println!();
    }
    println!("paper Fig 5: near-linear speedup with density on CPU-hosted KV.");
}
