//! Fig. 5 end-to-end bench: full decode step (selection + gather +
//! weighted attention) at several densities over long host-resident
//! caches, per-layer-slice at Llama-8B head shape; reports the measured
//! speedup curve that EXPERIMENTS.md compares against the paper's.
//!
//! Grown for the SIMD kernel pass with two extra sections:
//!
//! * **kernels** — single-thread scalar-vs-simd comparison of the fused
//!   decode step (score every key with the fused dequant-dot, then
//!   weight-accumulate V). "Scalar" is the `*_seq_ref` sequential
//!   dependency chain LLVM cannot vectorize; the dispatched kernel must
//!   beat it ≥2x (the CI-checked copy of this number lives in
//!   `BENCH_engine.json`'s `"kernels"` block, written by bench_engine).
//! * **allocation audit** — a one-shot counting `#[global_allocator]`
//!   proves the arena-backed hot path stops allocating once warm.
//!
//! Run: cargo bench --bench bench_decode_speedup

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use vattn::attention::{dense_sdpa, sparse_sdpa};
use vattn::policies::{IndexPolicy, PolicyCtx, VAttentionPolicy};
use vattn::tensor::quant::QuantizedMat4;
use vattn::tensor::simd;
use vattn::util::timer::bench;
use vattn::util::Rng;
use vattn::workloads::{synthesize_head, ScoreProfile};

/// Counting allocator: `System` plus a relaxed counter on every
/// alloc/realloc — the audit reads deltas around hot-path sections.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

/// One fused int4 decode step over `n` keys at head dim `d`: score every
/// key with the fused dequant-dot, softmax-stabilize, accumulate V.
/// `fused` and `accum` are the kernel pair under measurement.
fn fused_decode_step(
    qk: &QuantizedMat4,
    qv: &QuantizedMat4,
    q: &[f32],
    logits: &mut Vec<f32>,
    out: &mut Vec<f32>,
    fused: impl Fn(&QuantizedMat4, usize, &[f32]) -> f32,
    accum: impl Fn(f32, &[f32], &mut [f32]),
    maxf: impl Fn(&[f32]) -> f32,
) -> f32 {
    let n = qk.rows();
    logits.clear();
    for r in 0..n {
        logits.push(fused(qk, r, q));
    }
    let m = maxf(logits);
    out.clear();
    out.resize(q.len(), 0.0);
    let mut vrow: Vec<f32> = Vec::with_capacity(q.len());
    let mut denom = 0.0f32;
    for r in 0..n {
        let w = (logits[r] - m).exp();
        denom += w;
        vrow.clear();
        qv.dequantize_row_into(r, &mut vrow);
        accum(w, &vrow, out);
    }
    denom
}

fn kernels_section(rng: &mut Rng) {
    println!("== kernels: scalar (seq_ref) vs dispatched SIMD, single thread ==");
    println!("   dispatch: {}", simd::kernel_name());
    let budget = Duration::from_millis(400);
    let d = 128;
    let n = 8192;
    let mut qk = QuantizedMat4::new(d);
    let mut qv = QuantizedMat4::new(d);
    for _ in 0..n {
        let kr: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0)).collect();
        let vr: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0)).collect();
        qk.push_row(&kr);
        qv.push_row(&vr);
    }
    let q: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0) / (d as f32).sqrt()).collect();
    let mut logits = Vec::with_capacity(n);
    let mut out = Vec::with_capacity(d);

    let s_scalar = bench("fused int4 decode step (scalar seq_ref)", 1, budget, 3, || {
        fused_decode_step(
            &qk,
            &qv,
            &q,
            &mut logits,
            &mut out,
            |m, r, b| simd::dot_i4_seq_ref(m.row_packed(r), m.cols(), m.scale(r), b),
            simd::axpy_seq_ref,
            simd::max_fold_seq_ref,
        )
    });
    println!("{}", s_scalar.report());
    let s_simd = bench("fused int4 decode step (simd dispatch)", 1, budget, 3, || {
        fused_decode_step(
            &qk,
            &qv,
            &q,
            &mut logits,
            &mut out,
            |m, r, b| m.dot_row(r, b),
            simd::axpy,
            simd::max_fold,
        )
    });
    println!("{}", s_simd.report());
    let speedup = s_scalar.p50_s / s_simd.p50_s;
    println!("   fused decode speedup: {speedup:.2}x (gate: >= 2.0 in BENCH_engine.json)");

    // f32 dot for reference.
    let k_f32: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..d).map(|_| rng.normal32(0.0, 1.0)).collect())
        .collect();
    let s_dot_ref = bench("f32 dot scan (scalar seq_ref)", 1, budget, 3, || {
        let mut acc = 0.0f32;
        for row in &k_f32 {
            acc += simd::dot_seq_ref(row, &q);
        }
        acc
    });
    println!("{}", s_dot_ref.report());
    let s_dot = bench("f32 dot scan (simd dispatch)", 1, budget, 3, || {
        let mut acc = 0.0f32;
        for row in &k_f32 {
            acc += simd::dot(row, &q);
        }
        acc
    });
    println!("{}", s_dot.report());
    println!("   f32 dot speedup: {:.2}x", s_dot_ref.p50_s / s_dot.p50_s);
    println!();
}

fn allocation_audit(rng: &mut Rng) {
    println!("== allocation audit: arena-backed decode path ==");
    let d = 128;
    let n = 16_384;
    let head =
        synthesize_head(n, d, ScoreProfile::Mixed { heavy: 16, boost: 6.0, alpha: 0.9 }, rng);
    let mut cfg = vattn::experiments::common::vcfg(0.1);
    cfg.floor_at_base = false;
    let mut pol = VAttentionPolicy::oracle(cfg);
    let mut fork = rng.fork(7);
    let step = |pol: &mut VAttentionPolicy, fork: &mut Rng| {
        let mut ctx =
            PolicyCtx { k: &head.k, v: &head.v, q_scaled: &head.q_scaled, rng: fork, step: 0 };
        let sel = pol.select(&mut ctx);
        sparse_sdpa(&head.k, &head.v, &head.q_scaled, &sel)
    };
    // Warm up the arena and any policy-internal caches.
    for _ in 0..8 {
        let _ = step(&mut pol, &mut fork);
    }
    let (takes0, misses0) = vattn::util::arena::thread_counters();
    let a0 = alloc_count();
    let iters = 64u64;
    for _ in 0..iters {
        let _ = step(&mut pol, &mut fork);
    }
    let allocs = alloc_count() - a0;
    let (takes1, misses1) = vattn::util::arena::thread_counters();
    println!(
        "   {iters} warm decode steps: {allocs} global allocs ({:.1}/step), arena takes {} misses {}",
        allocs as f64 / iters as f64,
        takes1 - takes0,
        misses1 - misses0,
    );
    assert_eq!(misses1, misses0, "warm arena must not miss (allocation leak on hot path)");
    println!();
}

fn main() {
    let budget = Duration::from_millis(500);
    let mut rng = Rng::new(42);
    let d = 128; // llama-8b head dim

    kernels_section(&mut rng);
    allocation_audit(&mut rng);

    println!("== Fig 5: decode hot path at llama head shape (d=128) ==");
    for &n in &[16_384usize, 65_536, 131_072] {
        let head = synthesize_head(n, d, ScoreProfile::Mixed { heavy: 16, boost: 6.0, alpha: 0.9 }, &mut rng);
        let s_dense = bench(&format!("dense decode n={n}"), 1, budget, 3, || {
            dense_sdpa(&head.k, &head.v, &head.q_scaled)
        });
        println!("{}", s_dense.report());

        for eps in [0.05f64, 0.1, 0.2] {
            let mut cfg = vattn::experiments::common::vcfg(eps);
            cfg.floor_at_base = false;
            let mut pol = VAttentionPolicy::oracle(cfg);
            let mut fork = rng.fork(n as u64 ^ (eps * 1000.0) as u64);
            let mut density = 0.0f64;
            let mut iters = 0usize;
            let s = bench(&format!("vattention decode n={n} eps={eps}"), 1, budget, 3, || {
                let mut ctx = PolicyCtx { k: &head.k, v: &head.v, q_scaled: &head.q_scaled, rng: &mut fork, step: 0 };
                let sel = pol.select(&mut ctx);
                density += sel.density(n);
                iters += 1;
                sparse_sdpa(&head.k, &head.v, &head.q_scaled, &sel)
            });
            println!(
                "{}   density {:.3}  speedup {:.2}x",
                s.report(),
                density / iters as f64,
                s_dense.p50_s / s.p50_s
            );
        }
        println!();
    }
    println!("paper Fig 5: near-linear speedup with density on CPU-hosted KV.");
}
