//! Parallel continuous-batching engine bench: decode-throughput scaling
//! across worker counts on a 16-request batch (acceptance target: ≥ 2x
//! at 8 workers vs 1 on multi-core hosts, with byte-identical token
//! streams), dense-vs-vAttention modes, and an open-loop Poisson trace
//! with the TTFT/TPOT summary. The L3 coordinator numbers for
//! EXPERIMENTS.md §Perf.
//!
//! Also runs the shared-prefix demand-paging scenario: 16 requests with
//! a common 512-token system prompt served from a pool sized well below
//! the sum of worst-case leases — asserting completion, byte-identical
//! streams at 1 and 4 workers, a peak-block footprint under the
//! unshared baseline, and quiescence after drain + prefix flush.
//!
//! Also runs the verified KV quantization scenario: the same
//! shared-prompt workload on the same pool *bytes* at fp32 vs int8 vs
//! bit-packed int4 — asserting ≥ 3.5x (int8) and ≥ 6x (int4) KV
//! compression, monotonically fewer preemptions, and byte-identical
//! quantized streams at 1 and 4 workers — plus empirical quantized
//! (ε, δ) coverage estimates at both dtypes written to the `kv_quant`
//! JSON block (CI-checked).
//!
//! Also runs the kernel-dispatch comparison: the fused int4 decode step
//! (dequant-dot score scan + weighted V accumulation) timed single-
//! threaded against the sequential `*_seq_ref` scalar chain; the
//! measured speedup is written to the `kernels` JSON block and
//! CI-gated at ≥ 2x.
//!
//! Also runs the spill-to-disk cold-tier scenario: the shared-prompt
//! workload on an over-committed pool with the file-backed `SpillStore`
//! attached — asserting completion with zero full-replay preemptions,
//! streams byte-identical to the unconstrained spill-off baseline at
//! workers {1, 4}, aggregate swap-in bytes equal to spill-out bytes,
//! and a fresh session warm-starting from the persisted prefix store
//! with a nonzero hit rate on the same prompts. The same contended
//! workload then re-runs with `--kv-prefetch` staging cold-tier reads
//! on the spill-io thread: streams must stay byte-identical to both
//! baselines while blocking swap-in reads collapse to ≤ 10% of the
//! prefetch-off run's swap-ins (CI-gated via the `spill` JSON block's
//! `prefetch_hit_rate` / `blocking_swap_in_ops` fields).
//!
//! Also runs the temporal heavy-hitter reuse scenarios: a 4-request
//! 64-token-generation vAttention batch asserting reuse-on streams are
//! byte-identical to reuse-off at workers {1, 4}, and a planted
//! temporally-stable stream at the policy level asserting the drift
//! certificate cuts underlying top-k scans by ≥ 2× while selecting
//! exactly what a fresh policy selects.
//!
//! Also runs the network serving scenario: 1200 Poisson-scheduled
//! clients over real loopback TCP sockets against the sharded HTTP
//! front-end (4 shards, bounded admission queues) — asserting every
//! request resolves as a complete stream or a typed 429 shed (never a
//! stall), per-shard accounting sums to the client-side totals, and
//! p99 TTFT/TPOT stay under stall bounds; written to the `serving`
//! JSON block (CI-checked).
//!
//! Besides the human-readable report, writes `BENCH_engine.json`
//! (tokens/s plus TTFT/TPOT percentiles per worker count, the
//! `demand_paging` block with prefix-hit-rate / preemptions /
//! peak-block-utilization, the `spill` block with cold-tier spill-out /
//! swap-in traffic and the replay count, the `reuse` block with hit
//! rate / refresh causes / scan reduction, the `serving` block with
//! shed rate and socket-measured latency percentiles, and the
//! open-loop summary) so the perf
//! trajectory is machine-trackable PR over PR; CI checks the file is
//! produced and well-formed.
//!
//! Run: cargo bench --bench bench_engine

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use vattn::kvcache::KvDtype;
use vattn::metrics::{
    summarize, LatencySummary, PagingSummary, ReuseSummary, RouterSummary, ScenarioSummary,
    ServeSummary,
};
use vattn::model::{Model, ModelConfig, Sampler};
use vattn::policies::{
    IndexPolicy, PolicyCtx, ReuseConfig, ReuseStats, SizeSpec, TemporalReusePolicy,
    VAttentionPolicy,
};
use vattn::server::{
    AttentionMode, AttentionOpt, Engine, EngineConfig, Event, GenOptions, NetServer, Request,
    RequestResult, RouterConfig, Session, SubmitRequest,
};
use vattn::tensor::quant::QuantizedMat4;
use vattn::tensor::{simd, Mat};
use vattn::util::json::Json;
use vattn::util::timer::bench;
use vattn::workloads::harness::run_scenario;
use vattn::workloads::scenario::{axes_covered, matrix};
use vattn::workloads::traces::{generate_trace_seeded, to_requests, TraceConfig};
use vattn::util::Rng;

/// Mid-size model: heavy enough per step that a scheduler round
/// amortizes the pool's per-job overhead, light enough for a bench.
fn bench_model() -> ModelConfig {
    ModelConfig { d_model: 256, n_heads: 4, n_kv_heads: 4, n_layers: 4, d_ff: 512, vocab: 1024 }
}

fn requests_16() -> Vec<Request> {
    (0..16u64)
        .map(|i| {
            let ctx = 64 + 24 * (i as usize % 8); // 64..232 tokens
            let prompt: Vec<u32> = (0..ctx as u32).map(|t| (t * 31 + i as u32) % 1024).collect();
            Request::new(i, prompt, 24)
        })
        .collect()
}

fn engine(workers: usize) -> Engine<Model> {
    Engine::new(
        Model::new(bench_model(), 42),
        EngineConfig {
            max_batch: 16,
            sampler: Sampler::Greedy,
            seed: 1,
            workers,
            ..Default::default()
        },
    )
}

fn latency_json(s: &LatencySummary) -> Json {
    Json::obj()
        .field("p50", Json::num(s.p50))
        .field("p90", Json::num(s.p90))
        .field("p99", Json::num(s.p99))
        .field("mean", Json::num(s.mean))
        .field("max", Json::num(s.max))
}

fn main() {
    println!("== engine scaling: 16-request batch, gen 24, d=256 model ==");
    let run = |workers: usize| -> (f64, Vec<RequestResult>) {
        let eng = engine(workers);
        let t0 = Instant::now();
        let out = eng.serve(requests_16(), &AttentionMode::Dense).expect("serve");
        (t0.elapsed().as_secs_f64(), out)
    };
    let report = |out: &[RequestResult]| -> (usize, Vec<Vec<u32>>, Json, Json) {
        let tokens: usize = out.iter().map(|r| r.tokens.len()).sum();
        let streams: Vec<Vec<u32>> = out.iter().map(|r| r.tokens.clone()).collect();
        let ttft: Vec<f64> = out.iter().map(|r| r.ttft_s).collect();
        let tpot: Vec<f64> = out.iter().map(|r| r.tpot_s()).collect();
        (tokens, streams, latency_json(&summarize(&ttft)), latency_json(&summarize(&tpot)))
    };

    let mut scaling_rows: Vec<Json> = Vec::new();
    let (base_wall, base_out) = run(1);
    let (base_tokens, base_streams, base_ttft, base_tpot) = report(&base_out);
    println!(
        "workers  1  wall {base_wall:>6.2}s  throughput {:>7.1} tok/s  speedup vs 1 worker  1.00x",
        base_tokens as f64 / base_wall
    );
    scaling_rows.push(
        Json::obj()
            .field("workers", Json::num(1))
            .field("wall_s", Json::num(base_wall))
            .field("tokens", Json::num(base_tokens as f64))
            .field("tok_s", Json::num(base_tokens as f64 / base_wall))
            .field("speedup", Json::num(1.0))
            .field("ttft_s", base_ttft)
            .field("tpot_s", base_tpot),
    );
    for workers in [2usize, 4, 8] {
        let (wall, out) = run(workers);
        let (tokens, streams, ttft, tpot) = report(&out);
        assert_eq!(base_streams, streams, "token streams diverged at {workers} workers");
        println!(
            "workers {workers:>2}  wall {wall:>6.2}s  throughput {:>7.1} tok/s  speedup vs 1 worker {:>5.2}x",
            tokens as f64 / wall,
            base_wall / wall
        );
        scaling_rows.push(
            Json::obj()
                .field("workers", Json::num(workers as f64))
                .field("wall_s", Json::num(wall))
                .field("tokens", Json::num(tokens as f64))
                .field("tok_s", Json::num(tokens as f64 / wall))
                .field("speedup", Json::num(base_wall / wall))
                .field("ttft_s", ttft)
                .field("tpot_s", tpot),
        );
    }
    println!("token streams identical across all worker counts: OK");

    println!("\n== dense vs vAttention decode (8 workers) ==");
    let eng = engine(8);
    let mut mode_rows: Vec<Json> = Vec::new();
    for (label, mode) in [
        ("dense".to_string(), AttentionMode::Dense),
        (
            "vattention eps=0.1".to_string(),
            AttentionMode::Sparse(Box::new(move |_l, _h| {
                let mut c = vattn::experiments::common::vcfg(0.1);
                c.sink = SizeSpec::Abs(16);
                c.window = SizeSpec::Abs(32);
                c.verify = vattn::budget::Verify::Denominator;
                Box::new(VAttentionPolicy::oracle(c))
            })),
        ),
    ] {
        let t0 = Instant::now();
        let out = eng.serve(requests_16(), &mode).expect("serve");
        let wall = t0.elapsed().as_secs_f64();
        let tokens: usize = out.iter().map(|r| r.tokens.len()).sum();
        let decode_s: f64 = out.iter().map(|r| r.decode_s).sum();
        let density: f64 = out.iter().map(|r| r.mean_density).sum::<f64>() / out.len() as f64;
        let bytes: usize = out.iter().map(|r| r.kv_bytes_read).sum();
        println!(
            "{label:<22} wall {wall:>6.2}s  decode-tok/s {:>8.1}  density {density:>6.3}  kv-read {bytes:>12}",
            tokens as f64 / decode_s,
        );
        mode_rows.push(
            Json::obj()
                .field("mode", Json::str(label))
                .field("wall_s", Json::num(wall))
                .field("decode_tok_s", Json::num(tokens as f64 / decode_s))
                .field("density", Json::num(density))
                .field("kv_bytes_read", Json::num(bytes as f64)),
        );
    }

    println!("\n== shared-prefix demand paging: 16 requests, 512-token system prompt ==");
    // 16 requests share a 512-token system prompt (32 full blocks at 16
    // tokens/block) with distinct 32-token user suffixes and a 24-token
    // generation budget. Worst case is 36 blocks each — 576 in total —
    // but the pool holds only 128: demand paging + prefix sharing must
    // serve everyone anyway, byte-identically at 1 and 4 workers, and
    // end quiescent.
    let system_prompt: Vec<u32> = (0..512u32).map(|t| (t * 37 + 11) % 1024).collect();
    let prefix_prompts: Vec<Vec<u32>> = (0..16u32)
        .map(|i| {
            let mut p = system_prompt.clone();
            p.extend((0..32u32).map(|t| (t * 13 + i * 29 + 1) % 1024));
            p
        })
        .collect();
    let worst_case_blocks = 16 * (512 + 32 + 24usize).div_ceil(16);
    let cap_blocks = 128usize;
    assert!(cap_blocks < worst_case_blocks, "the scenario must undercut worst-case leasing");
    let run_paged = |workers: usize, cap_bytes: Option<usize>, prefix: bool, dtype: KvDtype| {
        let mut b = EngineConfig::builder()
            .max_batch(16)
            .seed(1)
            .workers(workers)
            .block_tokens(16)
            .prefix_cache(prefix)
            .kv_dtype(dtype);
        if let Some(cap) = cap_bytes {
            b = b.kv_capacity_bytes(cap);
        }
        let mut session = Session::new(Model::new(bench_model(), 42), b.build());
        let mut streams: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
        for p in &prefix_prompts {
            let id = session.submit(SubmitRequest::new(p.clone()).options(GenOptions::new(24)));
            streams.insert(id, Vec::new());
        }
        let t0 = Instant::now();
        while !session.is_idle() {
            for ev in session.tick().expect("tick") {
                match ev {
                    Event::Token { id, token, step, .. } => {
                        let st = streams.get_mut(&id).expect("known id");
                        assert_eq!(st.len(), step, "gapless streams across preemption");
                        st.push(token);
                    }
                    Event::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
                    _ => {}
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = session.stats();
        session.flush_prefix_cache().expect("flush");
        assert_eq!(session.kv_blocks_in_use(), 0, "quiescence: zero blocks after drain+flush");
        assert!(streams.values().all(|s| s.len() == 24), "all 16 must complete");
        (streams, stats, wall)
    };
    let fp32_block_bytes = 16 * bench_model().kv_bytes_per_token();
    let (unshared_streams, unshared_stats, unshared_wall) =
        run_paged(8, None, false, KvDtype::F32);
    let (shared1, shared_stats, shared_wall) =
        run_paged(1, Some(cap_blocks * fp32_block_bytes), true, KvDtype::F32);
    let (shared4, shared_stats4, _) =
        run_paged(4, Some(cap_blocks * fp32_block_bytes), true, KvDtype::F32);
    assert_eq!(shared1, shared4, "token streams diverged between 1 and 4 workers");
    assert_eq!(shared1, unshared_streams, "prefix forking changed a token stream");
    assert!(
        shared_stats.peak_blocks_in_use < unshared_stats.peak_blocks_in_use,
        "shared-prefix peak {} must undercut the unshared baseline {}",
        shared_stats.peak_blocks_in_use,
        unshared_stats.peak_blocks_in_use
    );
    let paging = PagingSummary::from(&shared_stats);
    println!(
        "pool {cap_blocks} blocks (worst-case sum {worst_case_blocks}): all 16 served; \
         peak {} vs unshared {}; wall {shared_wall:.2}s vs unshared {unshared_wall:.2}s (8 workers)",
        shared_stats.peak_blocks_in_use, unshared_stats.peak_blocks_in_use
    );
    println!("{}", paging.render());
    assert_eq!(
        shared_stats.preemptions, shared_stats4.preemptions,
        "paging decisions must be tick-deterministic, independent of workers"
    );
    assert_eq!(shared_stats.prefix_hit_blocks, shared_stats4.prefix_hit_blocks);

    println!("\n== verified int8 KV quantization: same pool bytes, fp32 vs int8 ==");
    // The same 16-request shared-prompt workload on the same *byte*
    // budget — 64 fp32 blocks' worth, below the fp32 run's peak demand.
    // Int8 rows are 3.5–4x smaller, so the identical budget yields ~4x
    // the blocks and the preemption pressure evaporates; the int8 runs
    // must still be byte-identical across worker counts.
    let quant_pool_bytes = 64 * fp32_block_bytes;
    let (_, q32_stats, _) = run_paged(8, Some(quant_pool_bytes), true, KvDtype::F32);
    let (q8_1, q8_stats, _) = run_paged(1, Some(quant_pool_bytes), true, KvDtype::Int8);
    let (q8_4, q8_stats4, _) = run_paged(4, Some(quant_pool_bytes), true, KvDtype::Int8);
    assert_eq!(q8_1, q8_4, "int8 streams diverged between 1 and 4 workers");
    assert_eq!(
        q8_stats.preemptions, q8_stats4.preemptions,
        "int8 paging decisions must be worker-count invariant"
    );
    assert!(
        q32_stats.preemptions > 0,
        "the planted pool must contend at fp32 (got {} preemptions)",
        q32_stats.preemptions
    );
    assert!(
        q8_stats.preemptions < q32_stats.preemptions,
        "int8 must preempt less than fp32 on the same pool ({} vs {})",
        q8_stats.preemptions,
        q32_stats.preemptions
    );
    assert!(
        q8_stats.preemptions * 4 <= q32_stats.preemptions,
        "int8 should cut preemptions ~4x ({} vs {})",
        q8_stats.preemptions,
        q32_stats.preemptions
    );
    let compression = q8_stats.kv_compression_ratio();
    assert!(compression >= 3.5, "int8 compression only {compression:.2}x");
    let quant_paging = PagingSummary::from(&q8_stats);
    println!(
        "pool {} KiB: fp32 {} preemptions vs int8 {} ({:.2}x KV compression, {} -> {} blocks)",
        quant_pool_bytes >> 10,
        q32_stats.preemptions,
        q8_stats.preemptions,
        compression,
        q32_stats.capacity_blocks.unwrap_or(0),
        q8_stats.capacity_blocks.unwrap_or(0),
    );
    println!("{}", quant_paging.render());

    // Bit-packed int4 on the same byte budget: rows shrink to
    // ⌈d/2⌉ + 4 B, so the identical pool holds ~7.5x the fp32 blocks
    // and preemption pressure can only drop further vs int8.
    let (q4_1, q4_stats, _) = run_paged(1, Some(quant_pool_bytes), true, KvDtype::Int4);
    let (q4_4, q4_stats4, _) = run_paged(4, Some(quant_pool_bytes), true, KvDtype::Int4);
    assert_eq!(q4_1, q4_4, "int4 streams diverged between 1 and 4 workers");
    assert_eq!(
        q4_stats.preemptions, q4_stats4.preemptions,
        "int4 paging decisions must be worker-count invariant"
    );
    assert!(
        q4_stats.preemptions <= q8_stats.preemptions,
        "int4 must not preempt more than int8 on the same pool ({} vs {})",
        q4_stats.preemptions,
        q8_stats.preemptions
    );
    let compression4 = q4_stats.kv_compression_ratio();
    assert!(compression4 >= 6.0, "int4 compression only {compression4:.2}x");
    assert!(
        q4_stats.capacity_blocks.unwrap_or(0) > q8_stats.capacity_blocks.unwrap_or(0),
        "the int4 pool must hold more blocks than int8 on the same bytes"
    );
    println!(
        "int4 on the same pool: {} preemptions ({:.2}x KV compression, {} blocks)",
        q4_stats.preemptions,
        compression4,
        q4_stats.capacity_blocks.unwrap_or(0),
    );

    // Empirical (ε, δ) coverage with quantized KV and the slack-widened
    // budget, measured against the exact fp32 population — the bench's
    // machine-readable companion to tests/budget_coverage.rs. `int4`
    // swaps the bit-packed codec in; the slack formula is shared (the
    // ~16x wider int4 scale widens ρ through the same `QuantSlack`).
    let quant_coverage = |bound: vattn::budget::Bound, seed: u64, int4: bool| -> f64 {
        use vattn::attention::{exact_num_den, weighted_num_den, Selection};
        use vattn::budget::{self, QuantSlack, Verify};
        use vattn::policies::sink_window_indices;
        use vattn::tensor::quant::QuantizedMat;
        use vattn::tensor::dot;
        let (n, d, eps, delta, trials) = (1024usize, 16usize, 0.2f64, 0.15f64, 30usize);
        let mut meta = Rng::new(seed);
        let mut violations = 0usize;
        for t in 0..trials {
            let mut rng = meta.fork(t as u64);
            let k = Mat::randn(n, d, 1.0, &mut rng);
            let v = Mat::randn(n, d, 1.0, &mut rng);
            let q: Vec<f32> =
                (0..d).map(|_| rng.normal32(0.0, 1.0) / (d as f32).sqrt()).collect();
            let quantize = |m: &Mat| {
                let mut out = Mat::zeros(0, d);
                if int4 {
                    let mut qm = QuantizedMat4::new(d);
                    for r in 0..m.rows {
                        qm.push_row(m.row(r));
                        qm.dequantize_row_into(r, &mut out.data);
                        out.rows += 1;
                    }
                    (out, qm.max_scale())
                } else {
                    let mut qm = QuantizedMat::new(d);
                    for r in 0..m.rows {
                        qm.push_row(m.row(r));
                        qm.dequantize_row_into(r, &mut out.data);
                        out.rows += 1;
                    }
                    (out, qm.max_scale())
                }
            };
            let (k_hat, k_scale) = quantize(&k);
            let (v_hat, v_scale) = quantize(&v);
            let i_f = sink_window_indices(n, 16, 16);
            let m_ref = i_f
                .iter()
                .map(|&i| dot(k_hat.row(i), &q))
                .fold(f32::NEG_INFINITY, f32::max);
            let base = budget::draw_base_sample(n, &i_f, 0.1, &mut rng);
            let stats = budget::estimate_stats(&k_hat, &v_hat, &q, &i_f, &base, m_ref);
            let bounds = vattn::tensor::quant::KvQuantBounds {
                k_scale_max: k_scale,
                v_scale_max: v_scale,
            };
            let slack = QuantSlack::from_bounds(&bounds, &q, d);
            let b = budget::budget_for_quant(&stats, Verify::Denominator, eps, delta, bound, Some(&slack))
                .max(base.len())
                .min(stats.n_s);
            let dyn_idx = rng.sample_excluding(n, b, &i_f);
            let sel = Selection::compose(i_f, dyn_idx, b as f32 / stats.n_s as f32);
            let (_, d_hat) = weighted_num_den(&k_hat, &v_hat, &q, &sel, m_ref);
            let (_, d_exact) = exact_num_den(&k, &v, &q, m_ref);
            if ((d_hat - d_exact) / d_exact).abs() > eps {
                violations += 1;
            }
        }
        violations as f64 / trials as f64
    };
    let coverage_fail_clt = quant_coverage(vattn::budget::Bound::Clt, 0xA5EED, false);
    let coverage_fail_hoeffding =
        quant_coverage(vattn::budget::Bound::Hoeffding, 0xB5EED, false);
    println!(
        "int8 (ε=0.2, δ=0.15) coverage: CLT fail rate {coverage_fail_clt:.3}, \
         Hoeffding fail rate {coverage_fail_hoeffding:.3}"
    );
    let coverage4_fail_clt = quant_coverage(vattn::budget::Bound::Clt, 0xC5EED, true);
    let coverage4_fail_hoeffding =
        quant_coverage(vattn::budget::Bound::Hoeffding, 0xD5EED, true);
    println!(
        "int4 (ε=0.2, δ=0.15) coverage: CLT fail rate {coverage4_fail_clt:.3}, \
         Hoeffding fail rate {coverage4_fail_hoeffding:.3}"
    );

    println!("\n== kernels: fused int4 decode step, seq_ref scalar vs dispatch ==");
    // Single-thread apples-to-apples: the same fused step (dequant-dot
    // score scan, max fold, weighted V accumulation) through the
    // sequential reference chain vs the dispatched kernel. The seq_ref
    // chain is a genuine latency-bound scalar loop — `#[inline(never)]`
    // single accumulators — so the ≥ 2x gate measures real kernel work,
    // not a strawman.
    let kern_budget = Duration::from_millis(300);
    let (kn, kd) = (4096usize, 128usize);
    let mut krng = Rng::new(0x5EED_4B17);
    let mut kqk = QuantizedMat4::new(kd);
    let mut kqv = QuantizedMat4::new(kd);
    for _ in 0..kn {
        let kr: Vec<f32> = (0..kd).map(|_| krng.normal32(0.0, 1.0)).collect();
        let vr: Vec<f32> = (0..kd).map(|_| krng.normal32(0.0, 1.0)).collect();
        kqk.push_row(&kr);
        kqv.push_row(&vr);
    }
    let kq: Vec<f32> =
        (0..kd).map(|_| krng.normal32(0.0, 1.0) / (kd as f32).sqrt()).collect();
    let mut klogits: Vec<f32> = Vec::with_capacity(kn);
    let mut kout: Vec<f32> = vec![0.0; kd];
    let mut kvrow: Vec<f32> = Vec::with_capacity(kd);
    let mut fused_step = |dot: &dyn Fn(usize) -> f32,
                          maxf: &dyn Fn(&[f32]) -> f32,
                          accum: &dyn Fn(f32, &[f32], &mut [f32])|
     -> f32 {
        klogits.clear();
        for r in 0..kn {
            klogits.push(dot(r));
        }
        let m = maxf(&klogits);
        kout.iter_mut().for_each(|x| *x = 0.0);
        let mut denom = 0.0f32;
        for r in 0..kn {
            let w = (klogits[r] - m).exp();
            denom += w;
            kvrow.clear();
            kqv.dequantize_row_into(r, &mut kvrow);
            accum(w, &kvrow, &mut kout);
        }
        denom
    };
    let s_kern_ref = bench("fused int4 step (scalar seq_ref)", 1, kern_budget, 3, || {
        fused_step(
            &|r| simd::dot_i4_seq_ref(kqk.row_packed(r), kqk.cols(), kqk.scale(r), &kq),
            &simd::max_fold_seq_ref,
            &simd::axpy_seq_ref,
        )
    });
    println!("{}", s_kern_ref.report());
    let s_kern_simd = bench("fused int4 step (simd dispatch)", 1, kern_budget, 3, || {
        fused_step(&|r| kqk.dot_row(r, &kq), &simd::max_fold, &simd::axpy)
    });
    println!("{}", s_kern_simd.report());
    let fused_speedup = s_kern_ref.p50_s / s_kern_simd.p50_s;
    println!(
        "dispatch {}: fused decode speedup {fused_speedup:.2}x (gate >= 2.0)",
        simd::kernel_name()
    );
    assert!(
        fused_speedup >= 2.0,
        "fused int4 decode step only {fused_speedup:.2}x over the scalar chain"
    );

    println!("\n== spill-to-disk cold tier: over-committed pool, swap-in preemption ==");
    // The same 16-request shared-prompt workload on the contended
    // 64-block pool, now with the file-backed cold tier attached:
    // preemption swaps the victim's KV blocks to disk and re-admission
    // swaps them back in, so the run must finish with zero full-replay
    // preemptions and token streams byte-identical to the unconstrained
    // spill-off baseline — at 1 and 4 workers, each on a fresh store so
    // both start cold. A brand-new session opening the first store then
    // warm-starts from the persisted prefix radix.
    let spill_file = |tag: &str| {
        let p = std::env::temp_dir()
            .join(format!("vattn-bench-{}-{tag}.spill", std::process::id()));
        let mut prefix = p.clone().into_os_string();
        prefix.push(".prefix");
        let _ = std::fs::remove_file(&p);
        let _ = std::fs::remove_file(std::path::PathBuf::from(prefix));
        p
    };
    let spill_a = spill_file("a");
    let spill_b = spill_file("b");
    let run_spill = |workers: usize, path: &std::path::Path, prefetch: bool| {
        let cfg = EngineConfig::builder()
            .max_batch(16)
            .seed(1)
            .workers(workers)
            .block_tokens(16)
            .prefix_cache(true)
            .kv_capacity_bytes(quant_pool_bytes)
            .kv_spill(path)
            .kv_prefetch(prefetch)
            .build();
        let mut session = Session::new(Model::new(bench_model(), 42), cfg);
        let mut streams: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
        for p in &prefix_prompts {
            let id = session.submit(SubmitRequest::new(p.clone()).options(GenOptions::new(24)));
            streams.insert(id, Vec::new());
        }
        let t0 = Instant::now();
        while !session.is_idle() {
            for ev in session.tick().expect("tick") {
                match ev {
                    Event::Token { id, token, step, .. } => {
                        let st = streams.get_mut(&id).expect("known id");
                        assert_eq!(st.len(), step, "gapless streams across swap-in");
                        st.push(token);
                    }
                    Event::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
                    _ => {}
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(
            session.spill_live_blocks(),
            Some(0),
            "no orphaned cold-tier blocks after drain"
        );
        let stats = session.stats();
        session.flush_prefix_cache().expect("flush");
        assert_eq!(session.kv_blocks_in_use(), 0, "quiescence after drain+flush");
        assert!(streams.values().all(|s| s.len() == 24), "all 16 must complete under spill");
        (streams, stats, wall)
    };
    let (sp1, sp_stats, sp_wall) = run_spill(1, &spill_a, false);
    let (sp4, sp_stats4, _) = run_spill(4, &spill_b, false);
    assert_eq!(sp1, sp4, "spill streams diverged between 1 and 4 workers");
    assert_eq!(sp1, unshared_streams, "the cold tier changed a token stream");
    assert!(sp_stats.preemptions > 0, "the planted pool must contend under spill");
    assert_eq!(
        sp_stats.preemption_replays, 0,
        "spill mode must never replay a preempted request"
    );
    assert_eq!(sp_stats4.preemption_replays, 0);
    assert!(sp_stats.spill_out_bytes > 0, "the contended run must spill to disk");
    assert_eq!(
        sp_stats.swap_in_bytes, sp_stats.spill_out_bytes,
        "every spilled byte must be swapped back in exactly once"
    );
    assert_eq!(sp_stats.swap_in_ops, sp_stats.spill_out_ops);
    assert_eq!(
        sp_stats.preemptions, sp_stats4.preemptions,
        "spill decisions must be tick-deterministic, independent of workers"
    );

    // Process-restart persistence: a brand-new session opening the same
    // store imports the prefix radix before any request arrives, and
    // serves the shared prompt from it with a nonzero hit rate.
    let warm_cfg = EngineConfig::builder()
        .max_batch(16)
        .seed(1)
        .workers(1)
        .block_tokens(16)
        .prefix_cache(true)
        .kv_capacity_bytes(quant_pool_bytes)
        .kv_spill(&spill_a)
        .build();
    let mut warm = Session::new(Model::new(bench_model(), 42), warm_cfg);
    let warm_held = warm.prefix_blocks_held();
    assert!(warm_held > 0, "warm start must import the persisted prefix radix");
    let warm_id =
        warm.submit(SubmitRequest::new(prefix_prompts[0].clone()).options(GenOptions::new(24)));
    let mut warm_tokens = Vec::new();
    while !warm.is_idle() {
        for ev in warm.tick().expect("tick") {
            if let Event::Token { id, token, .. } = ev {
                assert_eq!(id, warm_id);
                warm_tokens.push(token);
            }
        }
    }
    let warm_stats = warm.stats();
    assert!(
        warm_stats.prefix_hit_blocks > 0,
        "restarted session must hit the persisted prefix store"
    );
    assert_eq!(
        Some(&warm_tokens),
        sp1.get(&warm_id),
        "warm-started stream must match the cold run"
    );
    let warm_hit_rate = PagingSummary::from(&warm_stats).prefix_hit_rate;
    assert!(warm_hit_rate > 0.0);
    println!(
        "pool {} KiB + cold tier: {} preemptions, {} replays, {:.2} MiB out / {:.2} MiB in; \
         restart warm-started with {warm_held} prefix blocks (hit rate {warm_hit_rate:.2})",
        quant_pool_bytes >> 10,
        sp_stats.preemptions,
        sp_stats.preemption_replays,
        sp_stats.spill_out_bytes as f64 / (1u64 << 20) as f64,
        sp_stats.swap_in_bytes as f64 / (1u64 << 20) as f64,
    );
    println!("{}", PagingSummary::from(&sp_stats).render());

    println!("\n== async spill prefetch: staged cold-tier reads overlap compute ==");
    // The same over-committed workload with the prefetch pipeline on:
    // the spill-io thread starts reading a queue-front victim's slots
    // before a batch slot frees, so resume consumes staged buffers
    // instead of issuing blocking reads. Prefetch only moves data —
    // streams must stay byte-identical to the prefetch-off and
    // spill-off baselines at 1 and 4 workers (fresh stores, both cold),
    // with zero replays, a conserved prefetch ledger, and blocking
    // swap-in reads at ≤ 10% of the prefetch-off run's swap-ins.
    let spill_c = spill_file("c");
    let spill_d = spill_file("d");
    let (pf1, pf_stats, pf_wall) = run_spill(1, &spill_c, true);
    let (pf4, pf_stats4, _) = run_spill(4, &spill_d, true);
    assert_eq!(pf1, pf4, "prefetch streams diverged between 1 and 4 workers");
    assert_eq!(pf1, sp1, "prefetch changed a token stream vs the prefetch-off run");
    assert_eq!(pf1, unshared_streams, "prefetch changed a token stream vs the spill-off run");
    assert!(pf_stats.preemptions > 0, "the planted pool must contend under prefetch");
    assert_eq!(pf_stats.preemption_replays, 0, "prefetch mode must never replay");
    assert_eq!(pf_stats4.preemption_replays, 0);
    assert_eq!(
        pf_stats.preemptions, sp_stats.preemptions,
        "prefetch must not change the preemption schedule"
    );
    assert_eq!(
        pf_stats.swap_in_bytes, pf_stats.spill_out_bytes,
        "every spilled byte must be swapped back in exactly once under prefetch"
    );
    assert_eq!(pf_stats.swap_in_ops, pf_stats.spill_out_ops);
    assert!(pf_stats.prefetch_issued_ops > 0, "the contended run must issue prefetches");
    assert_eq!(
        pf_stats.prefetch_hit_ops + pf_stats.prefetch_wasted_ops,
        pf_stats.prefetch_issued_ops,
        "issued prefetch blocks must be consumed or wasted, never dropped"
    );
    assert_eq!(
        pf_stats.blocking_swap_in_ops + pf_stats.prefetch_hit_ops,
        pf_stats.swap_in_ops,
        "every swap-in is either staged or blocking"
    );
    assert!(
        pf_stats.blocking_swap_in_ops * 10 <= sp_stats.swap_in_ops,
        "blocking swap-ins under prefetch ({}) exceed 10% of the prefetch-off swap-ins ({})",
        pf_stats.blocking_swap_in_ops,
        sp_stats.swap_in_ops
    );
    let pf_paging = PagingSummary::from(&pf_stats);
    let pf_hit_rate = pf_paging.prefetch_hit_rate();
    let pf_overlap = pf_paging.swap_in_overlap_rate();
    println!(
        "prefetch on: {} issued / {} hit / {} wasted blocks (hit rate {pf_hit_rate:.2}); \
         blocking swap-ins {} of {} ({:.0}% overlapped); wall {pf_wall:.2}s vs {sp_wall:.2}s off",
        pf_stats.prefetch_issued_ops,
        pf_stats.prefetch_hit_ops,
        pf_stats.prefetch_wasted_ops,
        pf_stats.blocking_swap_in_ops,
        pf_stats.swap_in_ops,
        pf_overlap * 100.0,
    );
    println!("{}", pf_paging.render());
    for p in [&spill_a, &spill_b, &spill_c, &spill_d] {
        let mut prefix = p.clone().into_os_string();
        prefix.push(".prefix");
        let _ = std::fs::remove_file(p);
        let _ = std::fs::remove_file(std::path::PathBuf::from(prefix));
    }

    println!("\n== temporal heavy-hitter reuse: 4 requests, 64-token generation ==");
    // Long-generation vAttention serving with cross-step index reuse:
    // the per-(layer, head) heavy-hitter selection is cached and only
    // re-scored when the drift certificate fails, so the streams must be
    // byte-identical to reuse-off runs — at 1 and 4 workers — while the
    // underlying top-k scorer runs strictly less often.
    let reuse_prompts: Vec<Vec<u32>> = (0..4u32)
        .map(|i| (0..192u32).map(|t| (t * 31 + i * 7) % 1024).collect())
        .collect();
    let reuse_vcfg = {
        let mut c = vattn::experiments::common::vcfg(0.2);
        c.sink = SizeSpec::Abs(16);
        c.window = SizeSpec::Abs(32);
        c.verify = vattn::budget::Verify::Denominator;
        c
    };
    let run_reuse = |workers: usize, reuse: bool| -> (BTreeMap<u64, Vec<u32>>, ReuseStats) {
        let cfg = EngineConfig::builder().max_batch(4).seed(1).workers(workers).build();
        let mut session = Session::new(Model::new(bench_model(), 42), cfg);
        let mut streams: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
        for p in &reuse_prompts {
            let att = if reuse {
                AttentionOpt::VerifiedReuse(reuse_vcfg.clone(), ReuseConfig::default())
            } else {
                AttentionOpt::Verified(reuse_vcfg.clone())
            };
            let id = session
                .submit(SubmitRequest::new(p.clone()).options(GenOptions::new(64).attention(att)));
            streams.insert(id, Vec::new());
        }
        while !session.is_idle() {
            for ev in session.tick().expect("tick") {
                match ev {
                    Event::Token { id, token, .. } => streams.get_mut(&id).expect("id").push(token),
                    Event::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
                    _ => {}
                }
            }
        }
        (streams, session.stats().reuse)
    };
    let (off1, _) = run_reuse(1, false);
    let (off4, _) = run_reuse(4, false);
    let (on1, reuse_on1) = run_reuse(1, true);
    let (on4, reuse_on4) = run_reuse(4, true);
    assert_eq!(off1, off4, "reuse-off streams diverged across workers");
    assert_eq!(on1, on4, "reuse-on streams diverged across workers");
    assert_eq!(on1, off1, "temporal reuse changed a token stream");
    assert_eq!(reuse_on1, reuse_on4, "reuse decisions must be worker-count invariant");
    assert!(
        reuse_on1.scorer_calls <= reuse_on1.selects,
        "reuse can never scan more than once per select"
    );
    let engine_reuse = ReuseSummary::from(&reuse_on1);
    println!(
        "streams byte-identical reuse-on vs reuse-off at workers {{1, 4}}: OK \
         ({} tokens/request)",
        on1.values().next().map_or(0, Vec::len)
    );
    println!("{}", engine_reuse.render());

    // The certificate's headline saving on a temporally-stable stream,
    // at the policy level where it is provable: planted heavy hitters
    // plus a slowly drifting query. The wrapped scorer runs once (the
    // cold anchor); every later step is certified from the cache, so
    // the scan reduction equals the step count. Fresh-policy selections
    // are asserted identical along the way.
    println!("\n== temporal reuse, planted-stable stream (policy level) ==");
    let (synth_reduction, synth_stats) = {
        let n = 2048;
        let d = 32;
        let steps = 64;
        let mut rng = Rng::new(3);
        let mut k = Mat::randn(n, d, 0.1, &mut rng);
        let v = Mat::randn(n, d, 1.0, &mut rng);
        for j in 0..16 {
            let row = 200 + j * 5;
            for c in 0..d {
                k.set(row, c, if c == 0 { 10.0 } else { 0.0 });
            }
        }
        let mut cfg = vattn::experiments::common::vcfg(0.2);
        cfg.sink = SizeSpec::Abs(8);
        cfg.window = SizeSpec::Abs(16);
        cfg.heavy = SizeSpec::Abs(16);
        cfg.verify = vattn::budget::Verify::Denominator;
        let mut fresh = VAttentionPolicy::oracle(cfg.clone());
        let mut reused = TemporalReusePolicy::new(
            VAttentionPolicy::oracle(cfg),
            ReuseConfig { max_age: steps + 1, ..Default::default() },
        );
        let mut rng_a = Rng::new(7);
        let mut rng_b = Rng::new(7);
        for step in 0..steps {
            let mut qr = Rng::new(1000 + step as u64);
            let q: Vec<f32> = (0..d)
                .map(|c| if c == 0 { 1.0 } else { 0.0 } + 0.01 * qr.normal32(0.0, 1.0))
                .collect();
            let sa = fresh.select(&mut PolicyCtx {
                k: &k,
                v: &v,
                q_scaled: &q,
                rng: &mut rng_a,
                step,
            });
            let sb = reused.select(&mut PolicyCtx {
                k: &k,
                v: &v,
                q_scaled: &q,
                rng: &mut rng_b,
                step,
            });
            assert_eq!(sa.idx, sb.idx, "planted-stream selection diverged at step {step}");
            assert_eq!(sa.prob, sb.prob, "planted-stream probabilities diverged at step {step}");
        }
        (reused.stats().scorer_reduction(), reused.stats().clone())
    };
    assert!(
        synth_reduction >= 2.0,
        "stable stream must at least halve scorer invocations, got {synth_reduction:.2}x \
         ({synth_stats:?})"
    );
    println!(
        "selections identical to fresh policy; scorer invocations {} -> {} ({synth_reduction:.1}x fewer)",
        synth_stats.selects, synth_stats.scorer_calls
    );

    println!("\n== open-loop Poisson trace (rate 8 req/s, 24 requests, 8 workers) ==");
    let trace_cfg = TraceConfig {
        rate: 8.0,
        num_requests: 24,
        context_min: 64,
        context_max: 192,
        gen_min: 8,
        gen_max: 24,
    };
    let trace = generate_trace_seeded(&trace_cfg, 7);
    let requests = to_requests(&trace, bench_model().vocab);
    let t0 = Instant::now();
    let out = eng.serve_open_loop(requests, &AttentionMode::Dense).expect("open loop");
    let wall = t0.elapsed().as_secs_f64();
    let summary = ServeSummary::from_results(&out, wall);
    println!("{}", summary.render());

    println!("\n== network serving: 1200 Poisson arrivals over loopback sockets (4 shards) ==");
    // Open-loop load through real TCP connections against the sharded
    // HTTP front-end: 1200 clients fire on a Poisson schedule, each
    // holding its own socket and measuring TTFT / TPOT from its own
    // clock. Bounded admission (small per-shard queues under a bursty
    // arrival rate) turns overload into 429s; every client must resolve
    // as a complete stream or a typed shed — never a stall.
    let serve_shards = 4usize;
    let serve_depth = 6usize;
    let serve_trace = TraceConfig {
        rate: 800.0,
        num_requests: 1200,
        context_min: 16,
        context_max: 48,
        gen_min: 4,
        gen_max: 8,
    };
    let serve_arrivals = to_requests(&generate_trace_seeded(&serve_trace, 11), ModelConfig::tiny().vocab);
    let total_requests = serve_arrivals.len();
    let server = NetServer::start(
        Arc::new(Model::new(ModelConfig::tiny(), 42)),
        "127.0.0.1:0",
        RouterConfig::new(EngineConfig::builder().max_batch(16).seed(1).workers(1).build())
            .shards(serve_shards)
            .queue_depth(serve_depth),
    )
    .expect("bind loopback");
    let serve_addr = server.addr();
    let t_serve = Instant::now();
    let mut clients = Vec::with_capacity(total_requests);
    for ar in serve_arrivals {
        clients.push(
            std::thread::Builder::new()
                .stack_size(256 * 1024)
                .spawn(move || -> (u16, f64, f64, usize) {
                    let delay = ar.arrival_s - t_serve.elapsed().as_secs_f64();
                    if delay > 0.0 {
                        std::thread::sleep(Duration::from_secs_f64(delay));
                    }
                    let gen_len = ar.req.gen_len;
                    let toks: Vec<String> = ar.req.prompt.iter().map(u32::to_string).collect();
                    let body = format!(
                        "{{\"prompt\":[{}],\"gen_len\":{gen_len},\"seed\":{}}}",
                        toks.join(","),
                        ar.req.id
                    );
                    let mut s = TcpStream::connect(serve_addr).expect("connect");
                    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
                    let t_fire = Instant::now();
                    s.write_all(
                        format!(
                            "POST /v1/generate HTTP/1.1\r\nHost: b\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
                            body.len()
                        )
                        .as_bytes(),
                    )
                    .expect("send request");
                    let mut buf: Vec<u8> = Vec::with_capacity(1024);
                    let mut chunk = [0u8; 4096];
                    let mut t_first: Option<f64> = None;
                    loop {
                        let n = s.read(&mut chunk).expect("read stream (stall?)");
                        if n == 0 {
                            break;
                        }
                        buf.extend_from_slice(&chunk[..n]);
                        if t_first.is_none()
                            && String::from_utf8_lossy(&buf).contains("\"step\":0,")
                        {
                            t_first = Some(t_fire.elapsed().as_secs_f64());
                        }
                    }
                    let t_done = t_fire.elapsed().as_secs_f64();
                    let head = String::from_utf8_lossy(&buf);
                    let status: u16 = head
                        .split_whitespace()
                        .nth(1)
                        .and_then(|s| s.parse().ok())
                        .expect("status line");
                    (status, t_first.unwrap_or(t_done), t_done, gen_len)
                })
                .expect("spawn client"),
        );
    }
    let mut serve_ttfts: Vec<f64> = Vec::new();
    let mut serve_tpots: Vec<f64> = Vec::new();
    let mut serve_completed = 0u64;
    let mut serve_shed = 0u64;
    for c in clients {
        let (status, t_first, t_done, gen_len) = c.join().expect("client thread");
        match status {
            200 => {
                serve_completed += 1;
                serve_ttfts.push(t_first);
                if gen_len > 1 {
                    serve_tpots.push((t_done - t_first) / (gen_len - 1) as f64);
                }
            }
            429 => serve_shed += 1,
            other => panic!("unexpected serving status {other}"),
        }
    }
    let serve_wall = t_serve.elapsed().as_secs_f64();
    let shard_final = server.shutdown();
    assert_eq!(
        serve_completed + serve_shed,
        total_requests as u64,
        "every request must resolve as a stream or a typed shed"
    );
    assert_eq!(
        shard_final.iter().map(|s| s.received).sum::<u64>(),
        total_requests as u64,
        "per-shard received counts must sum to the client total"
    );
    assert_eq!(shard_final.iter().map(|s| s.completed).sum::<u64>(), serve_completed);
    assert_eq!(shard_final.iter().map(|s| s.shed).sum::<u64>(), serve_shed);
    let serve_shed_rate = serve_shed as f64 / total_requests as f64;
    assert!((0.0..=1.0).contains(&serve_shed_rate));
    let serve_ttft = summarize(&serve_ttfts);
    let serve_tpot = summarize(&serve_tpots);
    assert!(
        serve_ttft.p99.is_finite() && serve_ttft.p99 < 60.0,
        "p99 TTFT blew past the stall bound: {:.2}s",
        serve_ttft.p99
    );
    assert!(
        serve_tpot.p99.is_finite() && serve_tpot.p99 < 5.0,
        "p99 TPOT blew past the stall bound: {:.2}s",
        serve_tpot.p99
    );
    println!(
        "requests {total_requests}  completed {serve_completed}  shed {serve_shed} ({:.1}%)  \
         p50/p99 ttft {:.1}/{:.1} ms  p50/p99 tpot {:.2}/{:.2} ms  wall {serve_wall:.2}s",
        serve_shed_rate * 100.0,
        serve_ttft.p50 * 1e3,
        serve_ttft.p99 * 1e3,
        serve_tpot.p50 * 1e3,
        serve_tpot.p99 * 1e3,
    );
    println!("{}", RouterSummary::from_shards(&shard_final).render());

    println!("\n== scenario fuzz matrix: full differential sweep ==");
    // Every scenario the DSL enumerates (CI runs a 44-scenario sample in
    // tests/scenario_matrix.rs; the bench sweeps all of them) through
    // the differential oracle: byte-identical streams vs the reference
    // config, quiescent pools/spill slots after drain, replay counters
    // consistent with the spill mode, and empirical (ε, δ) coverage for
    // verified scenarios.
    let all_scenarios = matrix();
    let matrix_axes = axes_covered(&all_scenarios);
    let distinct_combos = all_scenarios
        .iter()
        .map(|s| s.code())
        .collect::<std::collections::BTreeSet<_>>()
        .len();
    // Distinct values on the resources axis: 4 once the spill+prefetch
    // arm is enumerated (ample / overcommit / spill / prefetch).
    let resource_axis_values = all_scenarios
        .iter()
        .map(|s| s.axis_codes()[3])
        .collect::<std::collections::BTreeSet<_>>()
        .len();
    let t_matrix = Instant::now();
    let mut matrix_failures: Vec<String> = Vec::new();
    let mut matrix_summary = ScenarioSummary::default();
    for sc in &all_scenarios {
        match run_scenario(*sc, 0xFA77) {
            Ok(r) => matrix_summary.record(
                true,
                r.requests,
                r.completed,
                r.cancelled,
                r.failed,
                r.preemptions,
                r.coverage_violation_rate,
            ),
            Err(e) => {
                matrix_summary.record(false, 0, 0, 0, 0, 0, None);
                matrix_failures.push(e);
            }
        }
    }
    let matrix_wall = t_matrix.elapsed().as_secs_f64();
    for f in &matrix_failures {
        println!("FAIL {f}");
    }
    println!("{}", matrix_summary.render());
    println!("axes {matrix_axes}  distinct combos {distinct_combos}  wall {matrix_wall:.1}s");
    assert!(
        matrix_failures.is_empty(),
        "{} scenarios failed the differential oracle",
        matrix_failures.len()
    );

    let json = Json::obj()
        .field("bench", Json::str("engine"))
        .field("batch", Json::num(16))
        .field("gen_len", Json::num(24))
        .field("d_model", Json::num(bench_model().d_model as f64))
        .field("scaling", Json::arr(scaling_rows))
        .field("modes", Json::arr(mode_rows))
        .field(
            "demand_paging",
            Json::obj()
                .field("requests", Json::num(16.0))
                .field("shared_prompt_tokens", Json::num(512.0))
                .field("capacity_blocks", Json::num(cap_blocks as f64))
                .field("worst_case_blocks", Json::num(worst_case_blocks as f64))
                .field("prefix_hit_rate", Json::num(paging.prefix_hit_rate))
                .field("preemptions", Json::num(paging.preemptions as f64))
                .field("peak_blocks_in_use", Json::num(paging.peak_blocks_in_use as f64))
                .field(
                    "unshared_peak_blocks_in_use",
                    Json::num(unshared_stats.peak_blocks_in_use as f64),
                )
                .field("cow_copies", Json::num(paging.cow_copies as f64))
                .field("wall_s", Json::num(shared_wall)),
        )
        .field(
            "kv_quant",
            Json::obj()
                .field("dtype", Json::str("int8"))
                .field("pool_bytes", Json::num(quant_pool_bytes as f64))
                .field(
                    "bytes_per_token_fp32",
                    Json::num(q8_stats.bytes_per_token_fp32 as f64),
                )
                .field("bytes_per_token_int8", Json::num(q8_stats.bytes_per_token as f64))
                .field("bytes_per_token_int4", Json::num(q4_stats.bytes_per_token as f64))
                .field("compression_ratio", Json::num(compression))
                .field("compression_ratio_int4", Json::num(compression4))
                .field("preemptions_fp32", Json::num(q32_stats.preemptions as f64))
                .field("preemptions_int8", Json::num(q8_stats.preemptions as f64))
                .field("preemptions_int4", Json::num(q4_stats.preemptions as f64))
                .field(
                    "capacity_blocks_fp32",
                    Json::num(q32_stats.capacity_blocks.unwrap_or(0) as f64),
                )
                .field(
                    "capacity_blocks_int8",
                    Json::num(q8_stats.capacity_blocks.unwrap_or(0) as f64),
                )
                .field(
                    "capacity_blocks_int4",
                    Json::num(q4_stats.capacity_blocks.unwrap_or(0) as f64),
                )
                .field("prefix_hit_rate", Json::num(quant_paging.prefix_hit_rate))
                .field("coverage_eps", Json::num(0.2))
                .field("coverage_delta", Json::num(0.15))
                .field("coverage_fail_clt", Json::num(coverage_fail_clt))
                .field("coverage_fail_hoeffding", Json::num(coverage_fail_hoeffding)),
        )
        .field(
            "kernels",
            Json::obj()
                .field("dispatch", Json::str(simd::kernel_name()))
                .field("fused_decode_speedup", Json::num(fused_speedup))
                .field("int4_compression_ratio", Json::num(compression4))
                .field("int4_coverage_fail_clt", Json::num(coverage4_fail_clt))
                .field(
                    "int4_coverage_fail_hoeffding",
                    Json::num(coverage4_fail_hoeffding),
                ),
        )
        .field(
            "spill",
            Json::obj()
                .field("requests", Json::num(16.0))
                .field("pool_bytes", Json::num(quant_pool_bytes as f64))
                .field("preemptions", Json::num(sp_stats.preemptions as f64))
                .field("preemption_replays", Json::num(sp_stats.preemption_replays as f64))
                .field("spill_out_bytes", Json::num(sp_stats.spill_out_bytes as f64))
                .field("spill_out_ops", Json::num(sp_stats.spill_out_ops as f64))
                .field("swap_in_bytes", Json::num(sp_stats.swap_in_bytes as f64))
                .field("swap_in_ops", Json::num(sp_stats.swap_in_ops as f64))
                .field("warm_start_prefix_blocks", Json::num(warm_held as f64))
                .field("warm_start_prefix_hit_rate", Json::num(warm_hit_rate))
                .field(
                    "blocking_swap_in_ops",
                    Json::num(pf_stats.blocking_swap_in_ops as f64),
                )
                .field(
                    "prefetch_issued_ops",
                    Json::num(pf_stats.prefetch_issued_ops as f64),
                )
                .field("prefetch_hit_ops", Json::num(pf_stats.prefetch_hit_ops as f64))
                .field(
                    "prefetch_wasted_ops",
                    Json::num(pf_stats.prefetch_wasted_ops as f64),
                )
                .field("prefetch_hit_rate", Json::num(pf_hit_rate))
                .field("swap_in_overlap_rate", Json::num(pf_overlap))
                .field("prefetch_wall_s", Json::num(pf_wall))
                .field("wall_s", Json::num(sp_wall)),
        )
        .field(
            "reuse",
            Json::obj()
                .field("requests", Json::num(4.0))
                .field("gen_len", Json::num(64.0))
                .field("selects", Json::num(engine_reuse.selects as f64))
                .field("hits", Json::num(engine_reuse.hits as f64))
                .field("hit_rate", Json::num(engine_reuse.hit_rate))
                .field("scorer_calls", Json::num(engine_reuse.scorer_calls as f64))
                .field("scorer_reduction", Json::num(engine_reuse.scorer_reduction))
                .field("refreshes", Json::num(engine_reuse.refreshes as f64))
                .field("refresh_cold", Json::num(engine_reuse.refresh_cold as f64))
                .field("refresh_max_age", Json::num(engine_reuse.refresh_max_age as f64))
                .field("refresh_drift", Json::num(engine_reuse.refresh_drift as f64))
                .field("refresh_budget", Json::num(engine_reuse.refresh_budget as f64))
                .field("refresh_grown", Json::num(engine_reuse.refresh_grown as f64))
                .field(
                    "refresh_unsupported",
                    Json::num(engine_reuse.refresh_unsupported as f64),
                )
                .field("survivors_scored", Json::num(engine_reuse.survivors_scored as f64))
                .field("synthetic_reduction", Json::num(synth_reduction)),
        )
        .field(
            "open_loop",
            Json::obj()
                .field("rate", Json::num(8.0))
                .field("requests", Json::num(summary.requests as f64))
                .field("tokens", Json::num(summary.tokens as f64))
                .field("throughput_tok_s", Json::num(summary.throughput_tok_s))
                .field("ttft_s", latency_json(&summary.ttft))
                .field("tpot_s", latency_json(&summary.tpot)),
        )
        .field(
            "serving",
            Json::obj()
                .field("transport", Json::str("loopback-http"))
                .field("shards", Json::num(serve_shards as f64))
                .field("queue_depth", Json::num(serve_depth as f64))
                .field("rate", Json::num(serve_trace.rate))
                .field("requests", Json::num(total_requests as f64))
                .field("completed", Json::num(serve_completed as f64))
                .field("shed", Json::num(serve_shed as f64))
                .field("shed_rate", Json::num(serve_shed_rate))
                .field("ttft_s", latency_json(&serve_ttft))
                .field("tpot_s", latency_json(&serve_tpot))
                .field(
                    "per_shard_received",
                    Json::arr(shard_final.iter().map(|s| Json::num(s.received as f64))),
                )
                .field("wall_s", Json::num(serve_wall)),
        )
        .field(
            "scenario_matrix",
            Json::obj()
                .field("scenarios", Json::num(matrix_summary.scenarios as f64))
                .field("failures", Json::num(matrix_summary.failures as f64))
                .field("axes_covered", Json::num(matrix_axes as f64))
                .field("distinct_combos", Json::num(distinct_combos as f64))
                .field("resource_axis_values", Json::num(resource_axis_values as f64))
                .field("requests", Json::num(matrix_summary.requests as f64))
                .field("preemptions", Json::num(matrix_summary.preemptions as f64))
                .field(
                    "coverage_checked",
                    Json::num(matrix_summary.coverage_checked as f64),
                )
                .field(
                    "coverage_violation_worst",
                    Json::num(matrix_summary.coverage_violation_worst),
                )
                .field("wall_s", Json::num(matrix_wall)),
        );
    let path = "BENCH_engine.json";
    std::fs::write(path, json.to_string() + "\n").expect("write BENCH_engine.json");
    println!("wrote {path}");
}
