//! Serving-engine throughput bench (rust-native backend): dense vs
//! vAttention decode over a batched trace. The L3 coordinator numbers
//! for EXPERIMENTS.md §Perf.
//!
//! Run: cargo bench --bench bench_engine

use std::time::Instant;

use vattn::model::{Model, ModelConfig, Sampler};
use vattn::policies::{SizeSpec, VAttentionPolicy};
use vattn::server::{AttentionMode, Engine, EngineConfig, Request};

fn run(engine: &Engine<Model>, mode: &AttentionMode, label: &str) {
    let requests: Vec<Request> = (0..6u64)
        .map(|i| {
            let ctx = 256 + 64 * i as usize;
            Request::new(i, (0..ctx as u32).map(|t| t % 250).collect(), 24)
        })
        .collect();
    let t0 = Instant::now();
    let out = engine.serve(requests, mode).expect("serve");
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = out.iter().map(|r| r.tokens.len()).sum();
    let decode_s: f64 = out.iter().map(|r| r.decode_s).sum();
    let density: f64 = out.iter().map(|r| r.mean_density).sum::<f64>() / out.len() as f64;
    let bytes: usize = out.iter().map(|r| r.kv_bytes_read).sum();
    println!(
        "{label:<22} wall {wall:>6.2}s  decode-tok/s {:>8.1}  density {density:>6.3}  kv-read {bytes:>12}",
        tokens as f64 / decode_s,
    );
}

fn main() {
    println!("== serving engine (tiny model, rust-native backend) ==");
    let engine = Engine::new(
        Model::new(ModelConfig::tiny(), 42),
        EngineConfig { max_batch: 3, sampler: Sampler::Greedy, seed: 1 },
    );
    run(&engine, &AttentionMode::Dense, "dense");
    for eps in [0.05, 0.1, 0.2] {
        let mode = AttentionMode::Sparse(Box::new(move |_l, _h| {
            let mut c = vattn::experiments::common::vcfg(eps);
            c.sink = SizeSpec::Abs(16);
            c.window = SizeSpec::Abs(32);
            Box::new(VAttentionPolicy::oracle(c))
        }));
        run(&engine, &mode, &format!("vattention eps={eps}"));
    }
}
