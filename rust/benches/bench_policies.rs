//! Index-selection latency per policy — the coordinator-side overhead a
//! deployment pays per (head, query). vAttention's selection must stay a
//! small fraction of the dense read it replaces (§Perf target).
//!
//! Run: cargo bench --bench bench_policies

use std::time::Duration;

use vattn::experiments::common::{knob_sweep, make_policy};
use vattn::policies::PolicyCtx;
use vattn::util::timer::bench;
use vattn::util::Rng;
use vattn::workloads::{synthesize_head, ScoreProfile};

fn main() {
    let budget = Duration::from_millis(300);
    let mut rng = Rng::new(42);
    let n = 32_768;
    let d = 128;
    let head = synthesize_head(n, d, ScoreProfile::Mixed { heavy: 16, boost: 6.0, alpha: 0.9 }, &mut rng);

    println!("== index-selection policies (n={n}, d={d}) ==");
    for m in [
        "oracle-top-k",
        "oracle-top-p",
        "random-sample",
        "hashattention",
        "double-sparsity",
        "quest",
        "pqcache",
        "infllm",
        "magicpig",
        "vattention-oracle",
        "vattention-hat",
    ] {
        let knob = knob_sweep(m)[2.min(knob_sweep(m).len() - 1)];
        let mut pol = make_policy(m, knob, 7);
        // Warm any auxiliary caches (signatures, codebooks, LSH tables)
        // outside the timed region — they amortize over a generation.
        {
            let mut fork = rng.fork(0);
            let mut ctx = PolicyCtx { k: &head.k, v: &head.v, q_scaled: &head.q_scaled, rng: &mut fork, step: 0 };
            let _ = pol.select(&mut ctx);
        }
        let mut fork = rng.fork(1);
        let s = bench(&format!("select {m}"), 1, budget, 3, || {
            let mut ctx = PolicyCtx { k: &head.k, v: &head.v, q_scaled: &head.q_scaled, rng: &mut fork, step: 1 };
            pol.select(&mut ctx)
        });
        println!("{}", s.report());
    }
}
