//! Verified-budget machinery micro-benchmarks: Φ⁻¹, stats estimation
//! from the base sample, and the Theorem-4.3 split search. Budget math
//! must be O(base sample), not O(n) (§Perf target).
//!
//! Run: cargo bench --bench bench_budget

use std::time::Duration;

use vattn::budget::{self, BaseStats, Bound, Verify};
use vattn::util::timer::bench;
use vattn::util::{inv_normal_cdf, Rng};
use vattn::workloads::{synthesize_head, ScoreProfile};

fn main() {
    let dur = Duration::from_millis(300);
    let mut rng = Rng::new(42);

    println!("== budget machinery ==");
    let s = bench("inv_normal_cdf", 10, dur, 10, || inv_normal_cdf(0.975));
    println!("{}", s.report());

    let n = 32_768;
    let d = 128;
    let head = synthesize_head(n, d, ScoreProfile::PowerLaw { alpha: 1.0 }, &mut rng);
    let i_f = vattn::policies::sink_window_indices(n, 128, 128);
    for rate in [0.01f64, 0.025, 0.05] {
        let base = budget::draw_base_sample(n, &i_f, rate, &mut rng);
        let blen = base.len();
        let s = bench(&format!("estimate_stats rate={rate} (b0={blen})"), 1, dur, 3, || {
            budget::estimate_stats(&head.k, &head.v, &head.q_scaled, &i_f, &base, 5.0)
        });
        println!("{}", s.report());
    }

    let stats = BaseStats {
        n_s: 32_000,
        sigma2_d: 0.8,
        trace_sigma_n: 40.0,
        d_hat: 30_000.0,
        n_hat_norm: 50_000.0,
        range_d: 4.0,
        range_n: 12.0,
        base_size: 800,
    };
    for (label, verify) in [
        ("budget_denominator", Verify::Denominator),
        ("budget_numerator", Verify::Numerator),
        ("budget_sdpa (Thm 4.3 grid)", Verify::Sdpa),
    ] {
        let s = bench(label, 10, dur, 10, || {
            budget::budget_for(&stats, verify, 0.05, 0.05, Bound::Clt)
        });
        println!("{}", s.report());
    }
}
