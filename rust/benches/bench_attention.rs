//! Attention hot-path benchmarks: dense SDPA, sparse SDPA at several
//! densities, and the raw logit scan. These are the L3 numbers behind
//! Fig. 5 (measured pane) and EXPERIMENTS.md §Perf.
//!
//! Run: cargo bench --bench bench_attention

use std::time::Duration;

use vattn::attention::{dense_sdpa, logits_all, sparse_sdpa, Selection};
use vattn::util::timer::bench;
use vattn::util::Rng;
use vattn::workloads::{synthesize_head, ScoreProfile};

fn main() {
    let budget = Duration::from_millis(400);
    let mut rng = Rng::new(42);
    println!("== attention kernels ==");

    for &(n, d) in &[(8_192usize, 128usize), (32_768, 128), (131_072, 128)] {
        let head = synthesize_head(n, d, ScoreProfile::PowerLaw { alpha: 1.0 }, &mut rng);
        let s = bench(&format!("logits_all n={n} d={d}"), 1, budget, 3, || {
            logits_all(&head.k, &head.q_scaled)
        });
        println!("{}", s.report());
        let gb = (n * d * 4) as f64 / s.p50_s / 1e9;
        println!("{:>60}", format!("-> K-scan bandwidth {gb:.2} GB/s"));

        let s_dense = bench(&format!("dense_sdpa n={n} d={d}"), 1, budget, 3, || {
            dense_sdpa(&head.k, &head.v, &head.q_scaled)
        });
        println!("{}", s_dense.report());

        for rho in [0.05f64, 0.10, 0.20] {
            let b = (n as f64 * rho) as usize;
            let mut fork = rng.fork(b as u64);
            let s = bench(&format!("sparse_sdpa n={n} rho={rho}"), 1, budget, 3, || {
                let idx = fork.sample_distinct(n, b);
                let sel = Selection::sampled(idx, rho as f32);
                sparse_sdpa(&head.k, &head.v, &head.q_scaled, &sel)
            });
            println!("{}   speedup {:.2}x", s.report(), s_dense.p50_s / s.p50_s);
        }
        println!();
    }
}
