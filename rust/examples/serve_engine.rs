//! End-to-end serving driver — the full three-layer stack on a real
//! (small) workload:
//!
//!   L1/L2: Pallas sparse-SDPA + JAX transformer blocks, AOT-lowered to
//!          the HLO artifacts under artifacts/ (`make artifacts`);
//!   L3:    this binary — rust loads the artifacts via PJRT, owns the
//!          host-resident KV caches, runs vAttention index selection per
//!          (layer, head) per token, and ships only the gathered rows to
//!          the attention executable.
//!
//! Serves a batched trace through the continuous-batching engine twice
//! (dense vs vAttention) and reports latency, throughput, density, KV
//! bytes moved, and dense-vs-sparse token agreement. Recorded in
//! EXPERIMENTS.md §E2E.
//!
//! Run: make artifacts && cargo run --release --features pjrt --example serve_engine
//! (the default offline build links the runtime stubs, which refuse to
//! load artifacts — the `pjrt` feature swaps in the real PJRT path).

use vattn::model::{Model, ModelConfig, Sampler};
use vattn::policies::{SizeSpec, VAttentionPolicy};
use vattn::runtime::{PjrtModel, Runtime};
use vattn::server::{AttentionMode, Engine, EngineConfig, Request};
use vattn::util::Rng;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        anyhow::bail!("no artifacts — run `make artifacts` first");
    }

    let cfg = ModelConfig::small();
    println!("loading artifacts + compiling on PJRT CPU ...");
    let rt = Runtime::load(&artifacts)?;
    println!("  artifacts: {:?}", rt.names());
    let native = Model::new(cfg.clone(), 42);
    println!(
        "  model: {} layers, d={}, {} heads, vocab {} (~{:.1}M params)",
        cfg.n_layers,
        cfg.d_model,
        cfg.n_heads,
        cfg.vocab,
        native.param_count() as f64 / 1e6
    );
    let pjrt = PjrtModel::new(rt, cfg.clone(), &native.w)?;

    // Workload: 4 long-context requests, 24 decode tokens each.
    let mut rng = Rng::new(9);
    let requests: Vec<Request> = (0..4u64)
        .map(|id| {
            let ctx_len = 320 + 128 * id as usize; // 320..704 tokens
            let prompt: Vec<u32> =
                (0..ctx_len as u32).map(|i| (i * 131 + id as u32 * 7) % 8000).collect();
            Request::new(id, prompt, 24)
        })
        .collect();
    let _ = &mut rng;

    // workers stays 1 on the PJRT backend until the bound xla crate's
    // thread-safety is verified — see the SAFETY note in pjrt_model.rs.
    let engine = Engine::new(
        pjrt,
        EngineConfig {
            max_batch: 2,
            sampler: Sampler::Greedy,
            seed: 1,
            workers: 1,
            ..Default::default()
        },
    );

    // ── dense pass ──
    println!("\nserving DENSE ...");
    let t0 = std::time::Instant::now();
    let dense = engine.serve(requests.clone(), &AttentionMode::Dense)?;
    let dense_wall = t0.elapsed().as_secs_f64();

    // ── vAttention pass ──
    println!("serving vATTENTION (eps=delta=0.1, denominator-verified) ...");
    let mode = AttentionMode::Sparse(Box::new(|_l, _h| {
        let mut c = vattn::experiments::common::vcfg(0.1);
        c.sink = SizeSpec::Abs(32);
        c.window = SizeSpec::Abs(64);
        c.heavy = SizeSpec::Frac(0.05);
        Box::new(VAttentionPolicy::oracle(c))
    }));
    let t0 = std::time::Instant::now();
    let sparse = engine.serve(requests, &mode)?;
    let sparse_wall = t0.elapsed().as_secs_f64();

    // ── report ──
    let tok: usize = dense.iter().map(|r| r.tokens.len()).sum();
    println!("\n{:=^72}", " results ");
    println!("{:<28} {:>12} {:>12}", "", "dense", "vattention");
    let sum = |rs: &[vattn::server::RequestResult], f: &dyn Fn(&vattn::server::RequestResult) -> f64| {
        rs.iter().map(f).sum::<f64>()
    };
    println!(
        "{:<28} {:>12.2} {:>12.2}",
        "wall clock (s)", dense_wall, sparse_wall
    );
    println!(
        "{:<28} {:>12.1} {:>12.1}",
        "decode throughput (tok/s)",
        tok as f64 / sum(&dense, &|r| r.decode_s),
        tok as f64 / sum(&sparse, &|r| r.decode_s)
    );
    println!(
        "{:<28} {:>12.3} {:>12.3}",
        "mean decode density",
        sum(&dense, &|r| r.mean_density) / dense.len() as f64,
        sum(&sparse, &|r| r.mean_density) / sparse.len() as f64
    );
    println!(
        "{:<28} {:>12} {:>12}",
        "KV bytes gathered (decode)",
        dense.iter().map(|r| r.kv_bytes_read).sum::<usize>(),
        sparse.iter().map(|r| r.kv_bytes_read).sum::<usize>()
    );
    let agree: usize = dense
        .iter()
        .zip(sparse.iter())
        .map(|(a, b)| a.tokens.iter().zip(b.tokens.iter()).filter(|(x, y)| x == y).count())
        .sum();
    println!(
        "{:<28} {:>12} {:>11.1}%",
        "token agreement", "-", agree as f64 / tok as f64 * 100.0
    );
    println!("\nall {} requests completed through the PJRT artifact path: OK", dense.len());
    Ok(())
}
