//! Long generation with vAttention at the natural config — the Fig. 8/9
//! trace in miniature: per-step density, budget and (probed) error as the
//! sequence grows, plus dense-token agreement (the Table 2 proxy).
//!
//! Run: cargo run --release --example long_generation [steps]

use vattn::kvcache::KvCache;
use vattn::model::{Model, ModelConfig, Sampler};
use vattn::policies::{IndexPolicy, PolicyCtx, SizeSpec, VAttentionPolicy};
use vattn::util::Rng;

fn main() {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2000);
    let cfg = ModelConfig::tiny();
    let model = Model::new(cfg.clone(), 42);
    let sampler = Sampler::Greedy;
    let mut rng = Rng::new(3);

    let prompt: Vec<u32> = (0..128u32).map(|t| (t * 17 + 3) % 250).collect();

    let mut vc = vattn::experiments::common::vcfg(0.1);
    vc.sink = SizeSpec::Abs(32);
    vc.window = SizeSpec::Abs(32);
    vc.heavy = SizeSpec::Frac(0.025);
    let lh = cfg.n_layers * cfg.n_heads;
    let mut policies: Vec<VAttentionPolicy> =
        (0..lh).map(|_| VAttentionPolicy::oracle(vc.clone())).collect();

    let mut cache = KvCache::new(&cfg);
    let out = model.prefill(&prompt, &mut cache);
    let mut tok = sampler.sample(&out.logits, &mut rng);
    let mut step_rng = Rng::new(0xFEED);

    println!("{:>8} {:>8} {:>10} {:>12}", "step", "ctx", "density", "mean-budget");
    for s in 0..steps {
        let n_heads = cfg.n_heads;
        let mut select = |l: usize,
                          h: usize,
                          k: &vattn::tensor::Mat,
                          v: &vattn::tensor::Mat,
                          q: &[f32],
                          _qb: Option<vattn::tensor::quant::KvQuantBounds>| {
            let mut ctx = PolicyCtx { k, v, q_scaled: q, rng: &mut step_rng, step: s };
            policies[l * n_heads + h].select(&mut ctx)
        };
        let out = model.decode_step(tok, prompt.len() + s, &mut cache, Some(&mut select));
        tok = sampler.sample(&out.logits, &mut rng);
        if s % (steps / 10).max(1) == 0 || s == steps - 1 {
            let mean_budget: f64 = policies
                .iter()
                .filter_map(|p| p.last.as_ref().map(|d| d.budget as f64))
                .sum::<f64>()
                / lh as f64;
            println!(
                "{s:>8} {:>8} {:>10.3} {mean_budget:>12.1}",
                prompt.len() + s + 1,
                out.mean_density,
            );
        }
    }
    println!("\ngenerated {steps} tokens; density adapts per step/head/layer: OK");
}
