//! Streaming session walkthrough: submit → tick → cancel.
//!
//! Three requests share one continuous batch, each with its own
//! generation options — the deployment story of the paper: accuracy
//! contracts are chosen per request at serving time, not baked into the
//! engine.
//!
//!   A: dense attention, greedy sampling (the reference stream);
//!   B: verified sparse attention with a per-request (ε, δ) contract;
//!   C: temperature sampling with its own RNG seed — cancelled
//!      mid-stream, which returns its KV blocks to the pool instantly.
//!
//! Token events are printed as the scheduler emits them, and an
//! `EventLog` turns the event timestamps into TTFT/TPOT numbers at the
//! end. The loop also handles `Event::Preempted` — with a bounded pool
//! (`EngineConfig::kv_capacity_bytes`) demand paging may park a request
//! mid-stream and deterministically resume it later; consumers just keep
//! reading, the token stream stays gapless.
//!
//! Run: cargo run --release --example streaming_session

use vattn::metrics::EventLog;
use vattn::model::{Model, ModelConfig, Sampler};
use vattn::policies::{SizeSpec, VAttentionConfig};
use vattn::server::{EngineConfig, Event, GenOptions, Session, SubmitRequest};

fn main() -> anyhow::Result<()> {
    let cfg = EngineConfig::builder().max_batch(3).workers(2).seed(7).build();
    let mut session = Session::new(Model::new(ModelConfig::tiny(), 42), cfg);
    let prompt: Vec<u32> = (0..192u32).map(|t| (t * 13 + 5) % 250).collect();

    // A: dense reference.
    let a = session.submit(SubmitRequest::new(prompt.clone()).options(GenOptions::new(12)));

    // B: verified sparse, this request's own contract. Tiny random-weight
    // models have unstructured values, so use the denominator guarantee
    // at a moderate tolerance to see genuine sparsity (cf. Fig. 10).
    let vcfg = VAttentionConfig {
        sink: SizeSpec::Abs(4),
        window: SizeSpec::Abs(8),
        heavy: SizeSpec::Frac(0.05),
        verify: vattn::budget::Verify::Denominator,
        ..Default::default()
    }
    .with_guarantee(0.2, 0.2);
    let b = session
        .submit(SubmitRequest::new(prompt.clone()).options(GenOptions::new(12).verified_with(vcfg)));

    // C: stochastic sampling on a pinned seed; will be cancelled.
    let c = session.submit(SubmitRequest::new(prompt).options(
        GenOptions::new(64).sampler(Sampler::Temperature(0.8)).seed(1234),
    ));
    let name = |id: u64| ["A(dense)", "B(verified ε=δ=0.2)", "C(temperature)"][id as usize];

    let mut log = EventLog::new();
    let mut c_tokens = 0usize;
    let mut cancelled = false;
    while !session.is_idle() {
        for ev in session.tick()? {
            log.record(&ev);
            match &ev {
                Event::Admitted { id, t_s } => {
                    println!("[{t_s:8.4}s] {:<20} admitted", name(*id));
                }
                Event::Token { id, token, step, t_s } => {
                    if *id == c {
                        c_tokens += 1;
                    }
                    println!("[{t_s:8.4}s] {:<20} token #{step:<3} = {token}", name(*id));
                }
                Event::Finished { id, result, t_s } => {
                    println!(
                        "[{t_s:8.4}s] {:<20} finished: {} tokens, density {:.3}, {} KV bytes read",
                        name(*id),
                        result.tokens.len(),
                        result.mean_density,
                        result.kv_bytes_read
                    );
                }
                Event::Preempted { id, t_s } => {
                    // Pool exhaustion sent the request back to the front
                    // of the queue; it will re-run deterministically and
                    // resume its token stream where it left off.
                    println!(
                        "[{t_s:8.4}s] {:<20} preempted (KV pool full) — will resume",
                        name(*id)
                    );
                }
                Event::Rejected { id, reason, t_s } => {
                    println!("[{t_s:8.4}s] {:<20} rejected: {reason}", name(*id));
                }
            }
        }
        if !cancelled && c_tokens >= 4 {
            let before = session.kv_blocks_in_use();
            session.cancel(c)?;
            cancelled = true;
            println!(
                "[{:8.4}s] {:<20} cancelled after {c_tokens} tokens: KV blocks {before} -> {}",
                session.now_s(),
                name(c),
                session.kv_blocks_in_use()
            );
        }
    }
    assert_eq!(session.kv_blocks_in_use(), 0, "drained session must hold zero KV blocks");

    println!("\nper-event latency (session clock):");
    for id in [a, b] {
        let t = log.timeline(id).expect("timeline");
        println!(
            "  {:<20} ttft {:>7.2}ms  tpot {:>7.2}ms  ({} tokens)",
            name(id),
            t.ttft_s().unwrap_or(0.0) * 1e3,
            t.tpot_s().unwrap_or(0.0) * 1e3,
            t.tokens
        );
    }
    let (ra, rb) = (&log.results()[0], &log.results()[1]);
    println!(
        "\nper-request contracts held in one batch: dense density {:.3}, verified density {:.3}",
        ra.mean_density.max(rb.mean_density),
        ra.mean_density.min(rb.mean_density)
    );
    println!("cancelled request streamed {c_tokens} tokens, then released every block: OK");
    Ok(())
}
