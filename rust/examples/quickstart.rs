//! Quickstart: one attention head, dense vs vAttention.
//!
//! Shows the core API in ~30 lines: build a KV cache, pick a tolerance
//! (ε, δ), let vAttention choose its adaptive budget, and compare the
//! sparse estimate against full attention.
//!
//! Run: cargo run --release --example quickstart

use vattn::attention::{dense_sdpa, sparse_sdpa};
use vattn::policies::{IndexPolicy, PolicyCtx, VAttentionConfig, VAttentionPolicy};
use vattn::tensor::rel_l2_error;
use vattn::util::Rng;
use vattn::workloads::{synthesize_head, ScoreProfile};

fn main() {
    let mut rng = Rng::new(42);

    // A 16K-token synthetic head with a realistic mixed score profile.
    let head = synthesize_head(
        16_384,
        64,
        ScoreProfile::Mixed { heavy: 16, boost: 6.0, alpha: 0.9 },
        &mut rng,
    );

    // Ground truth: full attention.
    let exact = dense_sdpa(&head.k, &head.v, &head.q_scaled).out;

    // vAttention with a user-specified tolerance: eps = delta = 0.05.
    let cfg = VAttentionConfig {
        eps: 0.05,
        delta: 0.05,
        verify: vattn::budget::Verify::Denominator,
        ..Default::default()
    };
    let mut policy = VAttentionPolicy::oracle(cfg);
    let mut ctx = PolicyCtx {
        k: &head.k,
        v: &head.v,
        q_scaled: &head.q_scaled,
        rng: &mut rng,
        step: 0,
    };
    let selection = policy.select(&mut ctx);
    let approx = sparse_sdpa(&head.k, &head.v, &head.q_scaled, &selection);

    let decision = policy.last.as_ref().unwrap();
    println!("vAttention quickstart");
    println!("  cache size n          : {}", head.k.rows);
    println!("  deterministic tokens  : {}", decision.n_fixed);
    println!("  adaptive sample budget: {}", decision.budget);
    println!("  density               : {:.3}", selection.density(head.k.rows));
    println!("  certificate           : (eps=0.05, delta=0.05) on the denominator");
    println!("  observed rel L2 error : {:.5}", rel_l2_error(&approx, &exact));
    assert!(rel_l2_error(&approx, &exact) < 0.15, "error far outside certificate");
    println!("OK");
}
