//! The verification dial: sweep ε and watch density fall and error rise
//! in lock-step — the user-controlled quality/efficiency trade-off of
//! Fig. 1 (right), in miniature.
//!
//! Run: cargo run --release --example verified_tradeoff

use vattn::attention::{dense_sdpa, sparse_sdpa};
use vattn::metrics::pearson;
use vattn::policies::{IndexPolicy, PolicyCtx, VAttentionPolicy};
use vattn::tensor::rel_l2_error;
use vattn::util::Rng;
use vattn::workloads::{synthesize_head, ScoreProfile};

fn main() {
    let mut rng = Rng::new(7);
    let head = synthesize_head(
        8_192,
        48,
        ScoreProfile::PowerLaw { alpha: 1.0 },
        &mut rng,
    );
    let exact = dense_sdpa(&head.k, &head.v, &head.q_scaled).out;

    println!("{:>8} {:>10} {:>12}", "eps", "density", "rel-error");
    let eps_grid = [0.01, 0.025, 0.05, 0.1, 0.2, 0.4];
    let mut errs = Vec::new();
    for &eps in &eps_grid {
        let mut cfg = vattn::experiments::common::vcfg(eps);
        cfg.floor_at_base = false;
        let mut policy = VAttentionPolicy::oracle(cfg);
        // average over a few random selections
        let (mut den, mut err) = (0.0, 0.0);
        let trials = 5;
        for t in 0..trials {
            let mut fork = rng.fork(t);
            let mut ctx = PolicyCtx {
                k: &head.k,
                v: &head.v,
                q_scaled: &head.q_scaled,
                rng: &mut fork,
                step: 0,
            };
            let sel = policy.select(&mut ctx);
            den += sel.density(head.k.rows) / trials as f64;
            err += rel_l2_error(&sparse_sdpa(&head.k, &head.v, &head.q_scaled, &sel), &exact)
                / trials as f64;
        }
        println!("{eps:>8.3} {den:>10.3} {err:>12.5}");
        errs.push(err);
    }
    let r = pearson(&eps_grid.to_vec(), &errs);
    println!("\nPearson r(eps, observed error) = {r:.3}  (paper: near-perfect correlation)");
    assert!(r > 0.8, "tolerance dial broken: r={r}");
    println!("OK");
}
