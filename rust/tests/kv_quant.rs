//! Verified quantized KV (int8 and bit-packed int4), held to the
//! engine's determinism bar: quantized token streams must be
//! byte-identical at any worker count, across a forced preemption
//! replay (including spill swap-in), and between prefix-cache-shared
//! and unshared runs (quantized payloads fork byte-for-byte; CoW never
//! aliases writes) — while the physical byte accounting (pool capacity,
//! TierStats traffic) reflects the ≥ 3.5× (int8) / ≥ 6× (int4)
//! compression the tiers exist for. The (ε, δ) correctness of the
//! quantized budget lives in `tests/budget_coverage.rs`; this file is
//! about serving semantics.

use std::collections::BTreeMap;

use vattn::kvcache::KvDtype;
use vattn::model::{Model, ModelConfig};
use vattn::server::{EngineConfig, Event, GenOptions, Session, SessionStats, SubmitRequest};

fn shared_prefix_prompts(n: usize, prefix_len: usize, suffix_len: usize) -> Vec<Vec<u32>> {
    let prefix: Vec<u32> = (0..prefix_len as u32).map(|t| (t * 31 + 7) % 250).collect();
    (0..n)
        .map(|i| {
            let mut p = prefix.clone();
            p.extend((0..suffix_len as u32).map(|t| (t * 13 + i as u32 * 17 + 3) % 250));
            p
        })
        .collect()
}

/// Submit every prompt with the given options, tick to idle, and return
/// (token streams in submission order, session stats).
fn run_session(
    cfg: EngineConfig,
    prompts: &[Vec<u32>],
    opts: GenOptions,
) -> (Vec<Vec<u32>>, SessionStats) {
    let mut s = Session::new(Model::new(ModelConfig::tiny(), 42), cfg);
    let mut streams: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
    for p in prompts {
        let id = s.submit(SubmitRequest::new(p.clone()).options(opts.clone()));
        streams.insert(id, Vec::new());
    }
    while !s.is_idle() {
        for ev in s.tick().expect("tick") {
            match ev {
                Event::Token { id, token, step, .. } => {
                    let st = streams.get_mut(&id).expect("token for known request");
                    assert_eq!(st.len(), step, "streams must stay gapless across preemption");
                    st.push(token);
                }
                Event::Finished { id, result, .. } => {
                    assert_eq!(result.tokens, streams[&id], "events must replay the result");
                }
                Event::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
                Event::Admitted { .. } | Event::Preempted { .. } => {}
            }
        }
    }
    let stats = s.stats();
    s.flush_prefix_cache().expect("flush");
    assert_eq!(s.kv_blocks_in_use(), 0, "drained + flushed session must be quiescent");
    (streams.into_values().collect(), stats)
}

fn int8_cfg() -> vattn::server::EngineConfigBuilder {
    EngineConfig::builder().seed(1).block_tokens(4).kv_dtype(KvDtype::Int8)
}

fn int4_cfg() -> vattn::server::EngineConfigBuilder {
    EngineConfig::builder().seed(1).block_tokens(4).kv_dtype(KvDtype::Int4)
}

#[test]
fn int8_streams_are_byte_identical_across_worker_counts() {
    // Dense and verified-sparse requests alike: quantization happens in
    // per-request caches inside a deterministic tick, so worker count
    // must not leak into the streams. The verified arm uses a small
    // sink/window so a real residual exists — its budget runs through
    // the quantization-slack path every decode step.
    let vcfg = vattn::policies::VAttentionConfig {
        sink: vattn::policies::SizeSpec::Abs(4),
        window: vattn::policies::SizeSpec::Abs(8),
        verify: vattn::budget::Verify::Denominator,
        ..Default::default()
    }
    .with_guarantee(0.3, 0.3);
    for verified in [false, true] {
        let (prompts, opts) = if verified {
            (shared_prefix_prompts(4, 56, 8), GenOptions::new(8).verified_with(vcfg.clone()))
        } else {
            (shared_prefix_prompts(6, 24, 8), GenOptions::new(8))
        };
        let (w1, _) = run_session(int8_cfg().workers(1).build(), &prompts, opts.clone());
        let (w4, _) = run_session(int8_cfg().workers(4).build(), &prompts, opts);
        assert_eq!(w1, w4, "int8 streams diverged across workers (verified={verified})");
        assert!(w1.iter().all(|s| s.len() == 8));
    }
}

#[test]
fn int8_preemption_replay_is_byte_identical() {
    // A pool too small for both long generations forces a preemption;
    // the replay re-quantizes the same rows, so the contended run must
    // reproduce the uncontended streams exactly.
    let mcfg = ModelConfig::tiny();
    let prompts = shared_prefix_prompts(2, 8, 0);
    let opts = GenOptions::new(12);
    // 7 int8 blocks < 2 × 5 worst-case: exhaustion mid-decode.
    let contended = int8_cfg()
        .max_batch(2)
        .kv_capacity_bytes(7 * 4 * KvDtype::Int8.kv_bytes_per_token(&mcfg))
        .build();
    let free = int8_cfg().max_batch(2).build();
    let (free_streams, free_stats) = run_session(free, &prompts, opts.clone());
    let (contended_streams, contended_stats) = run_session(contended, &prompts, opts);
    assert_eq!(free_stats.preemptions, 0);
    assert!(
        contended_stats.preemptions > 0,
        "7 blocks < 10 worst-case must force a preemption"
    );
    assert_eq!(
        free_streams, contended_streams,
        "int8 preemption replay must be byte-identical to the uncontended run"
    );
}

#[test]
fn int8_prefix_sharing_never_changes_streams() {
    // Shared vs unshared: the fork copies the donor's quantized payload
    // byte-for-byte (never requantizes), and full-block sharing keeps
    // CoW from ever aliasing a write — so streams must match exactly
    // and the shared run must actually hit the radix.
    let prompts = shared_prefix_prompts(6, 24, 6);
    let opts = GenOptions::new(6);
    let (unshared, unshared_stats) = run_session(int8_cfg().build(), &prompts, opts.clone());
    let (shared, shared_stats) = run_session(int8_cfg().prefix_cache(true).build(), &prompts, opts);
    assert_eq!(unshared, shared, "prefix forking changed an int8 token stream");
    assert_eq!(unshared_stats.prefix_hit_blocks, 0);
    assert!(shared_stats.prefix_hit_blocks > 0, "the shared run must fork cached blocks");
    assert!(
        shared_stats.peak_blocks_in_use <= unshared_stats.peak_blocks_in_use,
        "sharing must not grow the peak footprint"
    );
}

#[test]
fn int8_pool_holds_at_least_3_5x_more_blocks_for_the_same_bytes() {
    let mcfg = ModelConfig::tiny();
    let budget = 64 * 16 * mcfg.kv_bytes_per_token();
    let fp32 = EngineConfig::builder().block_tokens(16).kv_capacity_bytes(budget).build();
    let int8 = EngineConfig::builder()
        .block_tokens(16)
        .kv_capacity_bytes(budget)
        .kv_dtype(KvDtype::Int8)
        .build();
    let sf = Session::new(Model::new(mcfg.clone(), 42), fp32).stats();
    let si = Session::new(Model::new(mcfg, 42), int8).stats();
    assert_eq!(sf.capacity_blocks, Some(64));
    let ratio = si.capacity_blocks.unwrap() as f64 / 64.0;
    assert!(ratio >= 3.5, "same byte budget yields only {ratio}x the blocks at int8");
    assert!(si.kv_compression_ratio() >= 3.5);
    assert_eq!(si.kv_dtype, KvDtype::Int8);
}

#[test]
fn int4_streams_are_byte_identical_across_workers_preemption_and_spill() {
    // The bit-packed tier at the full determinism bar in one scenario:
    // the same workload on (a) an uncontended pool, (b) a pool too
    // small for both generations — forcing preemption replay — and
    // (c) the same contended pool with the cold tier attached — forcing
    // spill swap-in — each at 1 and 4 workers. All six runs must emit
    // the same bytes.
    let mcfg = ModelConfig::tiny();
    let prompts = shared_prefix_prompts(2, 8, 0);
    let opts = GenOptions::new(12);
    let cap = 7 * 4 * KvDtype::Int4.kv_bytes_per_token(&mcfg);
    let spill_path = std::env::temp_dir()
        .join(format!("vattn-test-int4-{}.spill", std::process::id()));
    let _ = std::fs::remove_file(&spill_path);

    let (free1, free_stats) =
        run_session(int4_cfg().max_batch(2).workers(1).build(), &prompts, opts.clone());
    let (free4, _) =
        run_session(int4_cfg().max_batch(2).workers(4).build(), &prompts, opts.clone());
    let (pre1, pre_stats) = run_session(
        int4_cfg().max_batch(2).workers(1).kv_capacity_bytes(cap).build(),
        &prompts,
        opts.clone(),
    );
    let (pre4, pre_stats4) = run_session(
        int4_cfg().max_batch(2).workers(4).kv_capacity_bytes(cap).build(),
        &prompts,
        opts.clone(),
    );
    let (sp1, sp_stats) = run_session(
        int4_cfg().max_batch(2).workers(1).kv_capacity_bytes(cap).kv_spill(&spill_path).build(),
        &prompts,
        opts.clone(),
    );
    let _ = std::fs::remove_file(&spill_path);
    let (sp4, sp_stats4) = run_session(
        int4_cfg().max_batch(2).workers(4).kv_capacity_bytes(cap).kv_spill(&spill_path).build(),
        &prompts,
        opts,
    );
    let _ = std::fs::remove_file(&spill_path);

    assert_eq!(free_stats.preemptions, 0);
    assert!(pre_stats.preemptions > 0, "7 int4 blocks < 10 worst-case must contend");
    assert_eq!(pre_stats.preemptions, pre_stats4.preemptions);
    assert!(sp_stats.spill_out_bytes > 0, "the contended spill run must swap out");
    assert_eq!(sp_stats.preemption_replays, 0, "spill mode must never replay");
    assert_eq!(sp_stats.swap_in_bytes, sp_stats.spill_out_bytes);
    assert_eq!(sp_stats.spill_out_bytes, sp_stats4.spill_out_bytes);
    assert_eq!(free1, free4, "int4 streams diverged across workers (uncontended)");
    assert_eq!(free1, pre1, "int4 preemption replay changed a stream");
    assert_eq!(pre1, pre4, "int4 streams diverged across workers (contended)");
    assert_eq!(free1, sp1, "int4 spill swap-in changed a stream");
    assert_eq!(sp1, sp4, "int4 streams diverged across workers (spill)");
}

#[test]
fn int4_pool_holds_at_least_6x_more_blocks_for_the_same_bytes() {
    let mcfg = ModelConfig::tiny();
    let budget = 64 * 16 * mcfg.kv_bytes_per_token();
    let fp32 = EngineConfig::builder().block_tokens(16).kv_capacity_bytes(budget).build();
    let int4 = EngineConfig::builder()
        .block_tokens(16)
        .kv_capacity_bytes(budget)
        .kv_dtype(KvDtype::Int4)
        .build();
    let sf = Session::new(Model::new(mcfg.clone(), 42), fp32).stats();
    let si = Session::new(Model::new(mcfg, 42), int4).stats();
    assert_eq!(sf.capacity_blocks, Some(64));
    let ratio = si.capacity_blocks.unwrap() as f64 / 64.0;
    assert!(ratio >= 6.0, "same byte budget yields only {ratio}x the blocks at int4");
    assert!(si.kv_compression_ratio() >= 6.0);
    assert_eq!(si.kv_dtype, KvDtype::Int4);
}

#[test]
fn wider_overrides_are_rejected_on_an_int4_pool_and_int4_is_admitted_anywhere() {
    // Both int8 and f32 rows are wider than int4's ⌈d/2⌉ + 4 — on a
    // byte-capped int4 pool either override must be rejected up front.
    // The narrower direction (int4 rows into an int8-sized pool) is
    // always admissible.
    let mcfg = ModelConfig::tiny();
    for wider in [KvDtype::Int8, KvDtype::F32] {
        let capped = int4_cfg()
            .kv_capacity_bytes(16 * 4 * KvDtype::Int4.kv_bytes_per_token(&mcfg))
            .build();
        let mut s = Session::new(Model::new(mcfg.clone(), 42), capped);
        let doomed = s.submit(
            SubmitRequest::new(shared_prefix_prompts(1, 8, 0)[0].clone())
                .options(GenOptions::new(4).kv_dtype(wider)),
        );
        let mut rejected = Vec::new();
        while !s.is_idle() {
            for ev in s.tick().expect("tick") {
                if let Event::Rejected { id, reason, .. } = ev {
                    rejected.push((id, format!("{reason}")));
                }
            }
        }
        assert_eq!(rejected.len(), 1, "{} override must be rejected", wider.name());
        assert_eq!(rejected[0].0, doomed);
        assert!(
            matches!(rejected[0].1.as_str(), m if m.contains("byte-capped pool")),
            "{}",
            rejected[0].1
        );
    }

    // int4 override on a byte-capped int8 pool: narrower, must serve.
    let capped8 = int8_cfg()
        .kv_capacity_bytes(16 * 4 * KvDtype::Int8.kv_bytes_per_token(&mcfg))
        .build();
    let mut s = Session::new(Model::new(mcfg, 42), capped8);
    s.submit(
        SubmitRequest::new(shared_prefix_prompts(1, 8, 0)[0].clone())
            .options(GenOptions::new(4).kv_dtype(KvDtype::Int4)),
    );
    let mut finished = 0;
    while !s.is_idle() {
        for ev in s.tick().expect("tick") {
            match ev {
                Event::Rejected { reason, .. } => {
                    panic!("narrower int4 override must be admitted: {reason}")
                }
                Event::Finished { .. } => finished += 1,
                _ => {}
            }
        }
    }
    assert_eq!(finished, 1);
}

#[test]
fn wider_dtype_override_is_rejected_on_a_byte_capped_pool() {
    // An f32 override into an int8-sized, byte-capped pool would hold
    // ~3.56x the bytes each block was charged for — the session must
    // reject it up front instead of silently overrunning the budget.
    // On an uncapped pool (and for narrower overrides) it is admitted.
    let mcfg = ModelConfig::tiny();
    let capped = int8_cfg()
        .kv_capacity_bytes(16 * 4 * KvDtype::Int8.kv_bytes_per_token(&mcfg))
        .build();
    let mut s = Session::new(Model::new(mcfg, 42), capped);
    let doomed = s.submit(
        SubmitRequest::new(shared_prefix_prompts(1, 8, 0)[0].clone())
            .options(GenOptions::new(4).kv_dtype(KvDtype::F32)),
    );
    let ok = s.submit(SubmitRequest::new(shared_prefix_prompts(1, 8, 0)[0].clone()));
    let mut rejected = Vec::new();
    let mut finished = Vec::new();
    while !s.is_idle() {
        for ev in s.tick().expect("tick") {
            match ev {
                Event::Rejected { id, reason, .. } => rejected.push((id, format!("{reason}"))),
                Event::Finished { id, .. } => finished.push(id),
                _ => {}
            }
        }
    }
    assert_eq!(rejected.len(), 1);
    assert_eq!(rejected[0].0, doomed);
    assert!(
        matches!(rejected[0].1.as_str(), m if m.contains("byte-capped pool")),
        "{}",
        rejected[0].1
    );
    assert_eq!(finished, vec![ok], "the inherited-dtype request must still serve");

    // Uncapped pool: the same override is fine.
    let mut free = Session::new(Model::new(ModelConfig::tiny(), 42), int8_cfg().build());
    free.submit(
        SubmitRequest::new(shared_prefix_prompts(1, 8, 0)[0].clone())
            .options(GenOptions::new(4).kv_dtype(KvDtype::F32)),
    );
    while !free.is_idle() {
        for ev in free.tick().expect("tick") {
            if let Event::Rejected { reason, .. } = ev {
                panic!("uncapped pool must admit a wider override: {reason}");
            }
        }
    }
}

#[test]
fn mixed_dtype_batch_is_deterministic_and_accounts_bytes_per_dtype() {
    // One session serving fp32, int8, and int4 requests concurrently
    // (dtype cycles by submission index). Dtype is per-request cache
    // state, so the mixed batch must (a) emit byte-identical streams at
    // 1 and 4 workers, (b) reproduce each request's stream from an
    // engine-wide run of its own dtype, and (c) charge each request its
    // own row width — f32 4d, int8 d + 4, int4 ⌈d/2⌉ + 4 bytes per
    // head-row — not a batch-blended rate.
    let d = ModelConfig::tiny().d_head();
    let prompts = shared_prefix_prompts(6, 20, 4);
    let dtypes = [None, Some(KvDtype::Int8), Some(KvDtype::Int4)];
    let gen = 6usize;
    let opts_for = |i: usize| {
        let o = GenOptions::new(gen).seed(500 + i as u64);
        match dtypes[i % 3] {
            Some(dt) => o.kv_dtype(dt),
            None => o,
        }
    };
    let run = |workers: usize| {
        let mut s = Session::new(
            Model::new(ModelConfig::tiny(), 42),
            EngineConfig::builder().seed(1).workers(workers).build(),
        );
        let mut ids = Vec::new();
        let mut streams: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
        let mut bytes: BTreeMap<u64, usize> = BTreeMap::new();
        for (i, p) in prompts.iter().enumerate() {
            let id = s.submit(SubmitRequest::new(p.clone()).options(opts_for(i)));
            streams.insert(id, Vec::new());
            ids.push(id);
        }
        while !s.is_idle() {
            for ev in s.tick().expect("tick") {
                match ev {
                    Event::Token { id, token, step, .. } => {
                        let st = streams.get_mut(&id).expect("token for known request");
                        assert_eq!(st.len(), step, "streams must stay gapless");
                        st.push(token);
                    }
                    Event::Finished { id, result, .. } => {
                        assert_eq!(result.tokens, streams[&id]);
                        bytes.insert(id, result.kv_bytes_written);
                    }
                    Event::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
                    _ => {}
                }
            }
        }
        ids.iter()
            .map(|id| (streams[id].clone(), bytes[id]))
            .collect::<Vec<(Vec<u32>, usize)>>()
    };
    let w1 = run(1);
    let w4 = run(4);
    assert_eq!(w1, w4, "mixed-dtype batch diverged across worker counts");
    assert!(w1.iter().all(|(s, _)| s.len() == gen));

    // (b) each stream matches the engine-wide run of its own dtype.
    for (i, (stream, _)) in w1.iter().enumerate() {
        let cfg = match dtypes[i % 3] {
            Some(dt) => EngineConfig::builder().seed(1).kv_dtype(dt).build(),
            None => EngineConfig::builder().seed(1).build(),
        };
        let (solo, _) = run_session(cfg, &prompts[i..i + 1], opts_for(i));
        assert_eq!(
            stream, &solo[0],
            "request {i} diverged from its engine-wide dtype run in the mixed batch"
        );
    }

    // (c) per-dtype write accounting: every request appends gen − 1
    // decode rows at C · row_bytes(d) for a batch-constant C, so the
    // byte ratio to the f32 request (index 0) must equal the row-width
    // ratio exactly.
    let f32_bytes = w1[0].1 as f64;
    for (i, (_, b)) in w1.iter().enumerate() {
        let dt = dtypes[i % 3].unwrap_or(KvDtype::F32);
        let want = dt.row_bytes(d) as f64 / KvDtype::F32.row_bytes(d) as f64;
        let got = *b as f64 / f32_bytes;
        assert!(
            (got - want).abs() < 1e-9,
            "request {i} ({}) charged {got:.6}x the f32 bytes; row widths say {want:.6}x",
            dt.name()
        );
    }
}

#[test]
fn per_request_int8_override_matches_engine_wide_int8() {
    // The GenOptions override must be byte-equivalent to configuring
    // the whole engine at int8 — including when the override request
    // serves alongside f32 neighbors in the same batch.
    let prompts = shared_prefix_prompts(1, 20, 4);
    let opts = GenOptions::new(6).seed(77);
    let (engine_wide, _) = run_session(int8_cfg().block_tokens(16).build(), &prompts, opts.clone());

    let mut s = Session::new(Model::new(ModelConfig::tiny(), 42), EngineConfig::default());
    let neighbor = s.submit(SubmitRequest::new(shared_prefix_prompts(1, 12, 0)[0].clone()));
    let target = s.submit(
        SubmitRequest::new(prompts[0].clone()).options(opts.kv_dtype(KvDtype::Int8)),
    );
    let mut streams: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
    let mut bytes: BTreeMap<u64, usize> = BTreeMap::new();
    while !s.is_idle() {
        for ev in s.tick().expect("tick") {
            match ev {
                Event::Token { id, token, .. } => streams.entry(id).or_default().push(token),
                Event::Finished { id, result, .. } => {
                    bytes.insert(id, result.kv_bytes_written);
                }
                Event::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
                _ => {}
            }
        }
    }
    assert_eq!(streams[&target], engine_wide[0], "override diverged from engine-wide int8");
    // Physical write traffic: the int8 request pays (d + 4)-byte rows,
    // its f32 neighbor 4·d, over the same per-token slot count. A
    // gen-G request appends G − 1 decode tokens after the post-prefill
    // counter reset.
    let d = ModelConfig::tiny().d_head();
    let per_append_ratio = (4 * d) as f64 / (d + 4) as f64;
    let int8_per_append = bytes[&target] as f64 / (6 - 1) as f64;
    let f32_per_append = bytes[&neighbor] as f64 / (16 - 1) as f64; // default gen_len 16
    assert!(
        (f32_per_append / int8_per_append - per_append_ratio).abs() < 1e-9,
        "physical write accounting off: f32 {f32_per_append} B/append vs int8 {int8_per_append}"
    );
}
