//! Cross-module integration tests: engine end-to-end on the rust-native
//! backend, policy × attention composition, and workload-level checks.

use vattn::attention::{dense_sdpa, sparse_sdpa};
use vattn::model::{Model, ModelConfig, Sampler};
use vattn::policies::*;
use vattn::server::{AttentionMode, Engine, EngineConfig, Request};
use vattn::tensor::rel_l2_error;
use vattn::util::Rng;
use vattn::workloads::{Task, TaskKind};

fn engine() -> Engine<Model> {
    Engine::new(Model::new(ModelConfig::tiny(), 42), EngineConfig::default())
}

#[test]
fn engine_vattention_tracks_dense_tokens_at_tight_eps() {
    // At a tight tolerance the verified engine should mostly agree with
    // dense decoding token-for-token.
    let eng = engine();
    let prompt: Vec<u32> = (0..160u32).map(|t| (t * 13 + 5) % 250).collect();
    let reqs = vec![Request::new(0, prompt, 16)];
    let dense = eng.serve(reqs.clone(), &AttentionMode::Dense).unwrap();
    let mode = AttentionMode::Sparse(Box::new(|_, _| {
        let mut c = vattn::experiments::common::vcfg(0.02);
        c.sink = SizeSpec::Abs(16);
        c.window = SizeSpec::Abs(32);
        Box::new(VAttentionPolicy::oracle(c))
    }));
    let sparse = eng.serve(reqs, &mode).unwrap();
    let agree = dense[0]
        .tokens
        .iter()
        .zip(sparse[0].tokens.iter())
        .filter(|(a, b)| a == b)
        .count();
    assert!(
        agree as f64 / 16.0 >= 0.75,
        "agreement {agree}/16 too low (tokens dense={:?} sparse={:?})",
        dense[0].tokens,
        sparse[0].tokens
    );
}

#[test]
fn engine_handles_mixed_generation_lengths() {
    let eng = engine();
    let reqs: Vec<Request> = (0..5u64)
        .map(|i| Request::new(i, vec![(i * 3) as u32 % 250; 8 + i as usize * 4], 2 + i as usize * 2))
        .collect();
    let out = eng.serve(reqs, &AttentionMode::Dense).unwrap();
    for (i, r) in out.iter().enumerate() {
        assert_eq!(r.tokens.len(), 2 + i * 2);
    }
}

#[test]
fn vattention_beats_plain_topk_on_aggregation_tasks() {
    // The headline claim at the task level: at matched density, composing
    // top-k with verified sampling recovers accuracy the truncated top-k
    // loses on long-tail tasks.
    let n = 4096;
    let d = 48;
    let trials = 12;
    let task = Task::new(TaskKind::Fwe, n, d);
    let mut rng = Rng::new(5);
    let (mut acc_topk, mut acc_vatt, mut den_topk, mut den_vatt) = (0.0, 0.0, 0.0, 0.0);
    for t in 0..trials {
        let inst = task.generate(&mut rng.fork(t));
        let dense = dense_sdpa(&inst.k, &inst.v, &inst.q_scaled).out;
        assert!(inst.score(&dense) > 0.0, "dense must solve the task");

        let mut topk = OracleTopKPolicy {
            sink: SizeSpec::Abs(64),
            window: SizeSpec::Abs(64),
            heavy: SizeSpec::Frac(0.03),
        };
        let mut fork = rng.fork(100 + t);
        let mut ctx = PolicyCtx { k: &inst.k, v: &inst.v, q_scaled: &inst.q_scaled, rng: &mut fork, step: 0 };
        let sel = topk.select(&mut ctx);
        den_topk += sel.density(n);
        acc_topk += inst.score(&sparse_sdpa(&inst.k, &inst.v, &inst.q_scaled, &sel));

        let mut vcfg = vattn::experiments::common::vcfg(0.1);
        vcfg.sink = SizeSpec::Abs(64);
        vcfg.window = SizeSpec::Abs(64);
        vcfg.heavy = SizeSpec::Frac(0.02);
        let mut vatt = VAttentionPolicy::oracle(vcfg);
        let mut fork = rng.fork(200 + t);
        let mut ctx = PolicyCtx { k: &inst.k, v: &inst.v, q_scaled: &inst.q_scaled, rng: &mut fork, step: 0 };
        let sel = vatt.select(&mut ctx);
        den_vatt += sel.density(n);
        acc_vatt += inst.score(&sparse_sdpa(&inst.k, &inst.v, &inst.q_scaled, &sel));
    }
    let tf = trials as f64;
    assert!(
        acc_vatt / tf >= acc_topk / tf + 0.25,
        "vattention {:.2} (density {:.3}) should beat top-k {:.2} (density {:.3})",
        acc_vatt / tf,
        den_vatt / tf,
        acc_topk / tf,
        den_topk / tf
    );
}

#[test]
fn all_policies_compose_with_sparse_attention() {
    // Every registered method produces a valid selection that yields a
    // finite attention output on a real task instance.
    use vattn::experiments::common::{knob_sweep, make_policy};
    let task = Task::new(TaskKind::Qa1, 2048, 48);
    let mut rng = Rng::new(11);
    let inst = task.generate(&mut rng);
    for m in [
        "oracle-top-k",
        "oracle-top-p",
        "random-sample",
        "hybrid",
        "streaming-llm",
        "hashattention",
        "double-sparsity",
        "quest",
        "pqcache",
        "infllm",
        "h2o",
        "snapkv",
        "magicpig",
        "vattention-oracle",
        "vattention-hat",
    ] {
        let knob = knob_sweep(m)[0];
        let mut pol = make_policy(m, knob, 3);
        let mut fork = rng.fork(1);
        let mut ctx = PolicyCtx { k: &inst.k, v: &inst.v, q_scaled: &inst.q_scaled, rng: &mut fork, step: 0 };
        let sel = pol.select(&mut ctx);
        sel.validate(2048).unwrap_or_else(|e| panic!("{m}: invalid selection: {e}"));
        let out = sparse_sdpa(&inst.k, &inst.v, &inst.q_scaled, &sel);
        assert!(out.iter().all(|x| x.is_finite()), "{m}: non-finite output");
    }
}

#[test]
fn dense_vs_full_selection_engine_equivalence() {
    // An engine with a policy that selects everything must emit exactly
    // the dense token stream.
    let eng = engine();
    let reqs = vec![Request::new(0, (0..40u32).collect(), 10)];
    let dense = eng.serve(reqs.clone(), &AttentionMode::Dense).unwrap();
    let mode = AttentionMode::Sparse(Box::new(|_, _| {
        Box::new(OracleTopPPolicy::new(1.0)) // p=1.0 -> every token
    }));
    let all = eng.serve(reqs, &mode).unwrap();
    assert_eq!(dense[0].tokens, all[0].tokens);
}

#[test]
fn temperature_sampling_end_to_end() {
    let eng = Engine::new(
        Model::new(ModelConfig::tiny(), 42),
        EngineConfig { max_batch: 2, sampler: Sampler::Temperature(0.8), seed: 77, ..Default::default() },
    );
    let out = eng
        .serve(vec![Request::new(0, vec![1, 2, 3, 4], 12)], &AttentionMode::Dense)
        .unwrap();
    assert_eq!(out[0].tokens.len(), 12);
}

#[test]
fn error_vs_density_is_monotone_for_vattention() {
    // Coarse property over the whole stack: tighter eps => denser
    // selection => lower error (averaged over tasks).
    use vattn::experiments::common::{eval_task, vcfg};
    let evaluate = |eps: f64| {
        eval_task(
            &|| Box::new(VAttentionPolicy::oracle(vcfg(eps))),
            TaskKind::Qa1,
            2048,
            48,
            1.0,
            8,
            9,
        )
    };
    let tight = evaluate(0.02);
    let loose = evaluate(0.4);
    assert!(tight.density >= loose.density, "density: {} vs {}", tight.density, loose.density);
    assert!(tight.err <= loose.err + 0.02, "err: {} vs {}", tight.err, loose.err);
}
