//! Statistical coverage of the (ε, δ)-verified budgets (Algorithm 2,
//! Lemma 4.1, Theorem 4.3): over many seeded trials, samples of the size
//! the budget machinery prescribes must violate the ε error bound in at
//! most ~δ of trials — for both verified computations the paper serves
//! with ({denominator, full SDPA}) and both concentration bounds
//! ({CLT, Hoeffding}). The CLT cells get extra slack: the bound is
//! asymptotic and the budget's statistics are themselves estimated from
//! the base sample (Figs. 11–15 show the same near-δ failure rates).
//! (Verify::Numerator is exercised indirectly by the SDPA cell; on
//! mean-zero random values its budget correctly saturates at n_s, which
//! makes a direct cell trivially covered.)

use vattn::attention::{dense_sdpa, exact_num_den, sparse_sdpa, weighted_num_den, Selection};
use vattn::budget::{self, Bound, Verify};
use vattn::policies::sink_window_indices;
use vattn::tensor::{dot, rel_l2_error, Mat};
use vattn::util::Rng;

const N: usize = 2000;
const D: usize = 16;
const EPS: f64 = 0.2;
const DELTA: f64 = 0.15;
const TRIALS: usize = 80;
const BASE_RATE: f64 = 0.1;

struct Trial {
    violated: bool,
    /// Prescribed budget as a fraction of the residual n_s.
    budget_frac: f64,
}

fn run_trial(verify: Verify, bound: Bound, rng: &mut Rng) -> Trial {
    let k = Mat::randn(N, D, 1.0, rng);
    let v = Mat::randn(N, D, 1.0, rng);
    let q: Vec<f32> = (0..D).map(|_| rng.normal32(0.0, 1.0) / (D as f32).sqrt()).collect();

    // Deterministic set and reference logit exactly as vAttention builds
    // them: sink + window, m_ref = max logit over the deterministic set.
    let i_f = sink_window_indices(N, 16, 16);
    let m_ref = i_f
        .iter()
        .map(|&i| dot(k.row(i), &q))
        .fold(f32::NEG_INFINITY, f32::max);

    let base = budget::draw_base_sample(N, &i_f, BASE_RATE, rng);
    let stats = budget::estimate_stats(&k, &v, &q, &i_f, &base, m_ref);
    let n_s = stats.n_s;
    // Floor at the base-sample size, as the paper's configs do.
    let b = budget::budget_for(&stats, verify, EPS, DELTA, bound)
        .max(base.len())
        .min(n_s);

    let dyn_idx = rng.sample_excluding(N, b, &i_f);
    let sel = Selection::compose(i_f, dyn_idx, b as f32 / n_s as f32);

    let violated = match verify {
        Verify::Denominator => {
            let (_, d_hat) = weighted_num_den(&k, &v, &q, &sel, m_ref);
            let (_, d_exact) = exact_num_den(&k, &v, &q, m_ref);
            ((d_hat - d_exact) / d_exact).abs() > EPS
        }
        Verify::Numerator => {
            let (n_hat, _) = weighted_num_den(&k, &v, &q, &sel, m_ref);
            let (n_exact, _) = exact_num_den(&k, &v, &q, m_ref);
            rel_l2_error(&n_hat, &n_exact) > EPS
        }
        Verify::Sdpa => {
            let exact = dense_sdpa(&k, &v, &q).out;
            let approx = sparse_sdpa(&k, &v, &q, &sel);
            rel_l2_error(&approx, &exact) > EPS
        }
    };
    Trial { violated, budget_frac: b as f64 / n_s as f64 }
}

fn violation_rate(verify: Verify, bound: Bound, seed: u64) -> (f64, f64) {
    let mut meta = Rng::new(seed);
    let mut violations = 0usize;
    let mut frac_sum = 0.0f64;
    for t in 0..TRIALS {
        let mut rng = meta.fork(t as u64);
        let trial = run_trial(verify, bound, &mut rng);
        if trial.violated {
            violations += 1;
        }
        frac_sum += trial.budget_frac;
    }
    (violations as f64 / TRIALS as f64, frac_sum / TRIALS as f64)
}

#[test]
fn denominator_clt_coverage() {
    let (rate, frac) = violation_rate(Verify::Denominator, Bound::Clt, 0xC0FFEE);
    assert!(rate <= DELTA + 0.05, "violation rate {rate} > δ={DELTA} (+slack), frac={frac}");
}

#[test]
fn denominator_hoeffding_coverage() {
    // Hoeffding is the conservative recipe: violations should be rare
    // even without slack.
    let (rate, frac) = violation_rate(Verify::Denominator, Bound::Hoeffding, 0xBEEF);
    assert!(rate <= DELTA, "violation rate {rate} > δ={DELTA}, frac={frac}");
}

#[test]
fn sdpa_clt_coverage() {
    let (rate, frac) = violation_rate(Verify::Sdpa, Bound::Clt, 0xFACE);
    assert!(rate <= DELTA + 0.05, "violation rate {rate} > δ={DELTA} (+slack), frac={frac}");
}

#[test]
fn sdpa_hoeffding_coverage() {
    let (rate, frac) = violation_rate(Verify::Sdpa, Bound::Hoeffding, 0xF00D);
    assert!(rate <= DELTA, "violation rate {rate} > δ={DELTA}, frac={frac}");
}

#[test]
fn clt_denominator_budgets_are_genuinely_sparse() {
    // Guard against vacuous coverage: on this workload the CLT
    // denominator budget must stay well below the full residual (i.e.
    // the test above is exercising real subsampling, not b == n_s).
    let (_, frac) = violation_rate(Verify::Denominator, Bound::Clt, 0xC0FFEE);
    assert!(frac < 0.6, "CLT budget fraction {frac} ~ dense; coverage test is vacuous");
    assert!(frac > 0.0);
}

#[test]
fn hoeffding_budgets_dominate_clt() {
    let mut meta = Rng::new(0xABCD);
    let mut clt_sum = 0usize;
    let mut hoef_sum = 0usize;
    for t in 0..20u64 {
        let mut rng = meta.fork(t);
        let k = Mat::randn(N, D, 1.0, &mut rng);
        let v = Mat::randn(N, D, 1.0, &mut rng);
        let q: Vec<f32> =
            (0..D).map(|_| rng.normal32(0.0, 1.0) / (D as f32).sqrt()).collect();
        let i_f = sink_window_indices(N, 16, 16);
        let m_ref = i_f
            .iter()
            .map(|&i| dot(k.row(i), &q))
            .fold(f32::NEG_INFINITY, f32::max);
        let base = budget::draw_base_sample(N, &i_f, BASE_RATE, &mut rng);
        let stats = budget::estimate_stats(&k, &v, &q, &i_f, &base, m_ref);
        clt_sum += budget::budget_for(&stats, Verify::Denominator, EPS, DELTA, Bound::Clt);
        hoef_sum +=
            budget::budget_for(&stats, Verify::Denominator, EPS, DELTA, Bound::Hoeffding);
    }
    assert!(hoef_sum > clt_sum, "hoeffding {hoef_sum} <= clt {clt_sum}");
}
