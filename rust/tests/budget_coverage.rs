//! Statistical coverage of the (ε, δ)-verified budgets (Algorithm 2,
//! Lemma 4.1, Theorem 4.3): over many seeded trials, samples of the size
//! the budget machinery prescribes must violate the ε error bound in at
//! most ~δ of trials — for both verified computations the paper serves
//! with ({denominator, full SDPA}) and both concentration bounds
//! ({CLT, Hoeffding}). The CLT cells get extra slack: the bound is
//! asymptotic and the budget's statistics are themselves estimated from
//! the base sample (Figs. 11–15 show the same near-δ failure rates).
//! (Verify::Numerator is exercised indirectly by the SDPA cell; on
//! mean-zero random values its budget correctly saturates at n_s, which
//! makes a direct cell trivially covered.)
//!
//! The quantized-KV sweep repeats the {Denominator, Sdpa} × {CLT,
//! Hoeffding} cells with int8-stored K/V and the widened budget
//! (`budget_for_quant`), measuring violations against the exact fp32
//! population — plus a negative control on adversarially coherent rows
//! proving coverage *fails* when the slack term is zeroed. The
//! bit-packed int4 tier repeats the denominator cells and both
//! adversarial controls with its ~16× wider scales flowing through the
//! same `QuantSlack` (docs/GUARANTEES.md §9).

use vattn::attention::{dense_sdpa, exact_num_den, sparse_sdpa, weighted_num_den, Selection};
use vattn::budget::{self, Bound, Verify};
use vattn::policies::sink_window_indices;
use vattn::tensor::{dot, rel_l2_error, Mat};
use vattn::util::Rng;

const N: usize = 2000;
const D: usize = 16;
const EPS: f64 = 0.2;
const DELTA: f64 = 0.15;
const TRIALS: usize = 80;
const BASE_RATE: f64 = 0.1;

struct Trial {
    violated: bool,
    /// Prescribed budget as a fraction of the residual n_s.
    budget_frac: f64,
}

fn run_trial(verify: Verify, bound: Bound, rng: &mut Rng) -> Trial {
    let k = Mat::randn(N, D, 1.0, rng);
    let v = Mat::randn(N, D, 1.0, rng);
    let q: Vec<f32> = (0..D).map(|_| rng.normal32(0.0, 1.0) / (D as f32).sqrt()).collect();

    // Deterministic set and reference logit exactly as vAttention builds
    // them: sink + window, m_ref = max logit over the deterministic set.
    let i_f = sink_window_indices(N, 16, 16);
    let m_ref = i_f
        .iter()
        .map(|&i| dot(k.row(i), &q))
        .fold(f32::NEG_INFINITY, f32::max);

    let base = budget::draw_base_sample(N, &i_f, BASE_RATE, rng);
    let stats = budget::estimate_stats(&k, &v, &q, &i_f, &base, m_ref);
    let n_s = stats.n_s;
    // Floor at the base-sample size, as the paper's configs do.
    let b = budget::budget_for(&stats, verify, EPS, DELTA, bound)
        .max(base.len())
        .min(n_s);

    let dyn_idx = rng.sample_excluding(N, b, &i_f);
    let sel = Selection::compose(i_f, dyn_idx, b as f32 / n_s as f32);

    let violated = match verify {
        Verify::Denominator => {
            let (_, d_hat) = weighted_num_den(&k, &v, &q, &sel, m_ref);
            let (_, d_exact) = exact_num_den(&k, &v, &q, m_ref);
            ((d_hat - d_exact) / d_exact).abs() > EPS
        }
        Verify::Numerator => {
            let (n_hat, _) = weighted_num_den(&k, &v, &q, &sel, m_ref);
            let (n_exact, _) = exact_num_den(&k, &v, &q, m_ref);
            rel_l2_error(&n_hat, &n_exact) > EPS
        }
        Verify::Sdpa => {
            let exact = dense_sdpa(&k, &v, &q).out;
            let approx = sparse_sdpa(&k, &v, &q, &sel);
            rel_l2_error(&approx, &exact) > EPS
        }
    };
    Trial { violated, budget_frac: b as f64 / n_s as f64 }
}

fn violation_rate(verify: Verify, bound: Bound, seed: u64) -> (f64, f64) {
    let mut meta = Rng::new(seed);
    let mut violations = 0usize;
    let mut frac_sum = 0.0f64;
    for t in 0..TRIALS {
        let mut rng = meta.fork(t as u64);
        let trial = run_trial(verify, bound, &mut rng);
        if trial.violated {
            violations += 1;
        }
        frac_sum += trial.budget_frac;
    }
    (violations as f64 / TRIALS as f64, frac_sum / TRIALS as f64)
}

#[test]
fn denominator_clt_coverage() {
    let (rate, frac) = violation_rate(Verify::Denominator, Bound::Clt, 0xC0FFEE);
    assert!(rate <= DELTA + 0.05, "violation rate {rate} > δ={DELTA} (+slack), frac={frac}");
}

#[test]
fn denominator_hoeffding_coverage() {
    // Hoeffding is the conservative recipe: violations should be rare
    // even without slack.
    let (rate, frac) = violation_rate(Verify::Denominator, Bound::Hoeffding, 0xBEEF);
    assert!(rate <= DELTA, "violation rate {rate} > δ={DELTA}, frac={frac}");
}

#[test]
fn sdpa_clt_coverage() {
    let (rate, frac) = violation_rate(Verify::Sdpa, Bound::Clt, 0xFACE);
    assert!(rate <= DELTA + 0.05, "violation rate {rate} > δ={DELTA} (+slack), frac={frac}");
}

#[test]
fn sdpa_hoeffding_coverage() {
    let (rate, frac) = violation_rate(Verify::Sdpa, Bound::Hoeffding, 0xF00D);
    assert!(rate <= DELTA, "violation rate {rate} > δ={DELTA}, frac={frac}");
}

#[test]
fn clt_denominator_budgets_are_genuinely_sparse() {
    // Guard against vacuous coverage: on this workload the CLT
    // denominator budget must stay well below the full residual (i.e.
    // the test above is exercising real subsampling, not b == n_s).
    let (_, frac) = violation_rate(Verify::Denominator, Bound::Clt, 0xC0FFEE);
    assert!(frac < 0.6, "CLT budget fraction {frac} ~ dense; coverage test is vacuous");
    assert!(frac > 0.0);
}

// ───────────────────────── quantized-KV sweep ─────────────────────────
//
// The int8 tier stores dequantized-lossy K/V; the budget must deliver
// (ε, δ) *inclusive of* that dequantization error: the estimator is
// built from the quantized rows, but coverage is measured against the
// exact fp32 population. `budget_for_quant` shrinks the sampling ε by
// the deterministic bias bound ρ and widens σ/range
// (docs/GUARANTEES.md §8); the negative control below proves the slack
// term is load-bearing by zeroing it on adversarial rows whose
// quantization error is coherent (≈ its worst-case bound) instead of
// cancelling.

/// Quantize every row of `m`, returning the dequantized mirror and the
/// largest row scale (what `KvCache::quant_bounds` reports).
fn quantize_mat(m: &Mat) -> (Mat, f32) {
    use vattn::tensor::quant::QuantizedMat;
    let mut q = QuantizedMat::new(m.cols);
    let mut out = Mat::zeros(0, m.cols);
    for r in 0..m.rows {
        q.push_row(m.row(r));
        q.dequantize_row_into(r, &mut out.data);
        out.rows += 1;
    }
    (out, q.max_scale())
}

/// The bit-packed mirror of [`quantize_mat`]: 15-level codes, ~16×
/// wider power-of-two scales, same `scale/2` per-element bound.
fn quantize_mat4(m: &Mat) -> (Mat, f32) {
    use vattn::tensor::quant::QuantizedMat4;
    let mut q = QuantizedMat4::new(m.cols);
    let mut out = Mat::zeros(0, m.cols);
    for r in 0..m.rows {
        q.push_row(m.row(r));
        q.dequantize_row_into(r, &mut out.data);
        out.rows += 1;
    }
    (out, q.max_scale())
}

/// Build the slack exactly as the serving policy does, via the single
/// `QuantSlack::from_bounds` conversion — so this sweep validates what
/// production charges, not a hand-copied formula.
fn quant_slack(k_scale: f32, v_scale: f32, q: &[f32], d: usize) -> budget::QuantSlack {
    let bounds =
        vattn::tensor::quant::KvQuantBounds { k_scale_max: k_scale, v_scale_max: v_scale };
    budget::QuantSlack::from_bounds(&bounds, q, d)
}

/// One quantized trial: budget + estimator over the dequantized (k̂, v̂),
/// violation measured against the exact fp32 (k, v). `quantize` picks
/// the codec (int8 or bit-packed int4), `with_slack` selects
/// `budget_for_quant` vs the slack-zeroed `budget_for`, and `floor`
/// applies the base-sample floor (off for the negative control, which
/// needs the raw prescribed budget).
fn run_trial_quant_with(
    quantize: fn(&Mat) -> (Mat, f32),
    verify: Verify,
    bound: Bound,
    k: &Mat,
    v: &Mat,
    q: &[f32],
    with_slack: bool,
    floor: bool,
    rng: &mut Rng,
) -> bool {
    let (k_hat, k_scale) = quantize(k);
    let (v_hat, v_scale) = quantize(v);
    let n = k.rows;
    let i_f = sink_window_indices(n, 16, 16);
    // m_ref from the dequantized logits, exactly as the policy sees them.
    let m_ref = i_f
        .iter()
        .map(|&i| dot(k_hat.row(i), q))
        .fold(f32::NEG_INFINITY, f32::max);
    let base = budget::draw_base_sample(n, &i_f, BASE_RATE, rng);
    let stats = budget::estimate_stats(&k_hat, &v_hat, q, &i_f, &base, m_ref);
    let n_s = stats.n_s;
    let slack = quant_slack(k_scale, v_scale, q, v.cols);
    let mut b = if with_slack {
        budget::budget_for_quant(&stats, verify, EPS, DELTA, bound, Some(&slack))
    } else {
        budget::budget_for(&stats, verify, EPS, DELTA, bound)
    };
    if floor {
        b = b.max(base.len());
    }
    let b = b.min(n_s);
    let dyn_idx = rng.sample_excluding(n, b, &i_f);
    let sel = Selection::compose(i_f, dyn_idx, b as f32 / n_s as f32);
    match verify {
        Verify::Denominator => {
            let (_, d_hat) = weighted_num_den(&k_hat, &v_hat, q, &sel, m_ref);
            let (_, d_exact) = exact_num_den(k, v, q, m_ref);
            ((d_hat - d_exact) / d_exact).abs() > EPS
        }
        Verify::Numerator => {
            let (n_hat, _) = weighted_num_den(&k_hat, &v_hat, q, &sel, m_ref);
            let (n_exact, _) = exact_num_den(k, v, q, m_ref);
            rel_l2_error(&n_hat, &n_exact) > EPS
        }
        Verify::Sdpa => {
            let exact = dense_sdpa(k, v, q).out;
            let approx = sparse_sdpa(&k_hat, &v_hat, q, &sel);
            rel_l2_error(&approx, &exact) > EPS
        }
    }
}

fn quant_violation_rate(
    quantize: fn(&Mat) -> (Mat, f32),
    verify: Verify,
    bound: Bound,
    seed: u64,
) -> f64 {
    let mut meta = Rng::new(seed);
    let mut violations = 0usize;
    for t in 0..TRIALS {
        let mut rng = meta.fork(t as u64);
        let k = Mat::randn(N, D, 1.0, &mut rng);
        let v = Mat::randn(N, D, 1.0, &mut rng);
        let q: Vec<f32> =
            (0..D).map(|_| rng.normal32(0.0, 1.0) / (D as f32).sqrt()).collect();
        if run_trial_quant_with(quantize, verify, bound, &k, &v, &q, true, true, &mut rng) {
            violations += 1;
        }
    }
    violations as f64 / TRIALS as f64
}

#[test]
fn quantized_denominator_clt_coverage() {
    let rate = quant_violation_rate(quantize_mat, Verify::Denominator, Bound::Clt, 0x1A8);
    assert!(rate <= DELTA + 0.05, "int8 CLT violation rate {rate} > δ={DELTA} (+slack)");
}

#[test]
fn quantized_denominator_hoeffding_coverage() {
    let rate = quant_violation_rate(quantize_mat, Verify::Denominator, Bound::Hoeffding, 0x2A8);
    assert!(rate <= DELTA, "int8 Hoeffding violation rate {rate} > δ={DELTA}");
}

#[test]
fn quantized_sdpa_clt_coverage() {
    let rate = quant_violation_rate(quantize_mat, Verify::Sdpa, Bound::Clt, 0x3A8);
    assert!(rate <= DELTA + 0.05, "int8 SDPA CLT violation rate {rate} > δ={DELTA} (+slack)");
}

#[test]
fn quantized_sdpa_hoeffding_coverage() {
    let rate = quant_violation_rate(quantize_mat, Verify::Sdpa, Bound::Hoeffding, 0x4A8);
    assert!(rate <= DELTA, "int8 SDPA Hoeffding violation rate {rate} > δ={DELTA}");
}

#[test]
fn int4_quantized_denominator_clt_coverage() {
    let rate = quant_violation_rate(quantize_mat4, Verify::Denominator, Bound::Clt, 0x7A8);
    assert!(rate <= DELTA + 0.05, "int4 CLT violation rate {rate} > δ={DELTA} (+slack)");
}

#[test]
fn int4_quantized_denominator_hoeffding_coverage() {
    let rate =
        quant_violation_rate(quantize_mat4, Verify::Denominator, Bound::Hoeffding, 0x8A8);
    assert!(rate <= DELTA, "int4 Hoeffding violation rate {rate} > δ={DELTA}");
}

/// Adversarial rows whose quantization error is *coherent*: every row
/// is `[127, c_i, …, c_i]` with `c_i = m_i + 0.49` — the leading 127
/// pins the power-of-two scale at exactly 1, and every tail element
/// dequantizes to `m_i` (an ≈ −0.49 shift), so with a non-negative
/// query all logits shift down together by ≈ 0.49·Σ_{j≥1} q_j instead
/// of cancelling. This is the population the worst-case slack bound
/// exists for.
fn adversarial_quant_instance() -> (Mat, Mat, Vec<f32>) {
    let k = Mat::from_fn(N, D, |r, c| {
        if c == 0 {
            127.0
        } else {
            // Varying integer levels keep a real residual variance so
            // the sampling term is non-trivial.
            (((r * 7 + r / 3) % 5) as f32) + 0.49
        }
    });
    // All-ones values quantize exactly (1.0 = 64 · 2⁻⁶ at the
    // power-of-two scale for max_abs 1), leaving the denominator as
    // the only biased quantity.
    let v = Mat::from_fn(N, D, |_, _| 1.0);
    let g = 0.0232f32;
    let mut q = vec![g; D];
    q[0] = 0.05;
    (k, v, q)
}

/// The int4 twin of [`adversarial_quant_instance`]: the leading 7.0
/// pins the 15-level power-of-two scale at exactly 1, so every tail
/// element `m_i + 0.49` again dequantizes to `m_i` — the same coherent
/// ≈ −0.49 shift, now produced by the bit-packed codec. The all-ones
/// values quantize exactly at int4 too (1.0 = 4 · 2⁻², scale 0.25 for
/// max_abs 1).
fn adversarial_quant_instance4() -> (Mat, Mat, Vec<f32>) {
    let k = Mat::from_fn(N, D, |r, c| {
        if c == 0 {
            7.0
        } else {
            (((r * 7 + r / 3) % 5) as f32) + 0.49
        }
    });
    let v = Mat::from_fn(N, D, |_, _| 1.0);
    let g = 0.0232f32;
    let mut q = vec![g; D];
    q[0] = 0.05;
    (k, v, q)
}

#[test]
fn quantized_coverage_holds_on_adversarial_rows_with_slack() {
    // The coherent-bias population, slack ON: the bias bound ρ here
    // exceeds ε, so the budget saturates at n_s (exact summation over
    // the quantized rows) and the only residual error is the true
    // coherent bias ≈ 1 − e^{−0.17} ≈ 0.16 < ε — zero violations.
    let mut meta = Rng::new(0x5A8);
    for t in 0..20u64 {
        let mut rng = meta.fork(t);
        let (k, v, q) = adversarial_quant_instance();
        let violated = run_trial_quant_with(
            quantize_mat,
            Verify::Denominator,
            Bound::Clt,
            &k,
            &v,
            &q,
            true,
            false,
            &mut rng,
        );
        assert!(!violated, "slack-on adversarial trial {t} violated ε={EPS}");
    }
}

#[test]
fn quantized_coverage_fails_when_slack_is_zeroed() {
    // Negative control proving the slack term is load-bearing: same
    // adversarial population, slack zeroed (plain `budget_for` over the
    // quantized stats). The estimator now concentrates around the
    // biased D_q ≈ e^{−0.17}·D with a sampling tolerance budgeted for
    // the full ε, so |D̂ − D|/D > ε far more often than δ permits.
    let mut meta = Rng::new(0x6A8);
    let mut violations = 0usize;
    for t in 0..TRIALS {
        let mut rng = meta.fork(t as u64);
        let (k, v, q) = adversarial_quant_instance();
        if run_trial_quant_with(
            quantize_mat,
            Verify::Denominator,
            Bound::Clt,
            &k,
            &v,
            &q,
            false,
            false,
            &mut rng,
        ) {
            violations += 1;
        }
    }
    let rate = violations as f64 / TRIALS as f64;
    assert!(
        rate > DELTA + 0.05,
        "zeroed slack still covered (rate {rate} ≤ {}): the quantization slack term \
         would be dead weight",
        DELTA + 0.05
    );
}

#[test]
fn int4_quantized_coverage_holds_on_adversarial_rows_with_slack() {
    // Same coherent-bias mechanics through the bit-packed codec: int4's
    // ρ (scale 1 pinned by the leading 7.0) exceeds ε, the budget
    // saturates, and the residual coherent bias stays under ε.
    let mut meta = Rng::new(0x9A8);
    for t in 0..20u64 {
        let mut rng = meta.fork(t);
        let (k, v, q) = adversarial_quant_instance4();
        let violated = run_trial_quant_with(
            quantize_mat4,
            Verify::Denominator,
            Bound::Clt,
            &k,
            &v,
            &q,
            true,
            false,
            &mut rng,
        );
        assert!(!violated, "int4 slack-on adversarial trial {t} violated ε={EPS}");
    }
}

#[test]
fn int4_quantized_coverage_fails_when_slack_is_zeroed() {
    // The int4 negative control: zero the (wider) int4 slack on the
    // coherent rows and the violation rate must blow past δ — proving
    // the ~16× wider ρ folded through `QuantSlack` is load-bearing for
    // the bit-packed tier, not inherited dead weight from int8.
    let mut meta = Rng::new(0xAA8);
    let mut violations = 0usize;
    for t in 0..TRIALS {
        let mut rng = meta.fork(t as u64);
        let (k, v, q) = adversarial_quant_instance4();
        if run_trial_quant_with(
            quantize_mat4,
            Verify::Denominator,
            Bound::Clt,
            &k,
            &v,
            &q,
            false,
            false,
            &mut rng,
        ) {
            violations += 1;
        }
    }
    let rate = violations as f64 / TRIALS as f64;
    assert!(
        rate > DELTA + 0.05,
        "zeroed int4 slack still covered (rate {rate} ≤ {}): the int4 slack term \
         would be dead weight",
        DELTA + 0.05
    );
}

#[test]
fn hoeffding_budgets_dominate_clt() {
    let mut meta = Rng::new(0xABCD);
    let mut clt_sum = 0usize;
    let mut hoef_sum = 0usize;
    for t in 0..20u64 {
        let mut rng = meta.fork(t);
        let k = Mat::randn(N, D, 1.0, &mut rng);
        let v = Mat::randn(N, D, 1.0, &mut rng);
        let q: Vec<f32> =
            (0..D).map(|_| rng.normal32(0.0, 1.0) / (D as f32).sqrt()).collect();
        let i_f = sink_window_indices(N, 16, 16);
        let m_ref = i_f
            .iter()
            .map(|&i| dot(k.row(i), &q))
            .fold(f32::NEG_INFINITY, f32::max);
        let base = budget::draw_base_sample(N, &i_f, BASE_RATE, &mut rng);
        let stats = budget::estimate_stats(&k, &v, &q, &i_f, &base, m_ref);
        clt_sum += budget::budget_for(&stats, Verify::Denominator, EPS, DELTA, Bound::Clt);
        hoef_sum +=
            budget::budget_for(&stats, Verify::Denominator, EPS, DELTA, Bound::Hoeffding);
    }
    assert!(hoef_sum > clt_sum, "hoeffding {hoef_sum} <= clt {clt_sum}");
}
