//! Blocking CI slice of the scenario fuzz matrix (ISSUE 9 / ROADMAP
//! item 5): a deterministically sampled subset of `workloads::scenario::
//! matrix()` — spanning every value of all six axes — runs through the
//! differential oracle in `workloads::harness`. Every scenario must
//! produce byte-identical streams against the reference configuration,
//! quiescent pools and spill slots after drain+flush, replay counters
//! consistent with its spill mode, and (for verified scenarios) an
//! empirical (ε, δ) coverage rate within bound. The full 846-scenario
//! sweep runs in `bench_engine` and lands in BENCH_engine.json's
//! CI-checked `"scenario_matrix"` block.

use vattn::workloads::harness::run_scenario;
use vattn::workloads::scenario::{axes_covered, matrix, sample};

/// Pinned sample seed: changing it is fine (any sample must pass), but
/// pinning keeps CI failures reproducible locally.
const SAMPLE_SEED: u64 = 0x5CE4A410;
/// Oracle base seed (workload randomness forks from this per scenario).
const BASE_SEED: u64 = 0xFA77;
/// Scenarios in the blocking slice (acceptance floor is 40).
const SAMPLE_N: usize = 44;

#[test]
fn full_matrix_spans_every_axis() {
    let all = matrix();
    assert!(all.len() >= 40, "matrix shrank to {} scenarios", all.len());
    assert_eq!(axes_covered(&all), 6);
}

#[test]
fn sampled_slice_is_deterministic_and_covering() {
    let all = matrix();
    let slice = sample(&all, SAMPLE_N, SAMPLE_SEED);
    assert_eq!(slice.len(), SAMPLE_N);
    assert_eq!(slice, sample(&all, SAMPLE_N, SAMPLE_SEED), "sample is not deterministic");
    assert_eq!(axes_covered(&slice), 6, "CI slice must span all six axes");
}

/// The matrix itself: every sampled scenario through the oracle. One
/// test (not per-scenario) so a failure reports the whole run's tally
/// and scenarios keep executing after the first bad one.
#[test]
fn sampled_matrix_passes_the_differential_oracle() {
    let all = matrix();
    let slice = sample(&all, SAMPLE_N, SAMPLE_SEED);
    let mut failures: Vec<String> = Vec::new();
    let mut completed = 0usize;
    let mut cancelled = 0usize;
    let mut failed_requests = 0usize;
    let mut preemptions = 0u64;
    let mut coverage_checked = 0usize;
    for sc in &slice {
        match run_scenario(*sc, BASE_SEED) {
            Ok(report) => {
                completed += report.completed;
                cancelled += report.cancelled;
                failed_requests += report.failed;
                preemptions += report.preemptions;
                if report.coverage_violation_rate.is_some() {
                    coverage_checked += 1;
                }
            }
            Err(e) => failures.push(e),
        }
    }
    assert!(
        failures.is_empty(),
        "{} of {} scenarios failed the oracle:\n{}",
        failures.len(),
        slice.len(),
        failures.join("\n")
    );
    // Sanity that the matrix exercised real behavior, not vacuous runs:
    // most requests complete, faults actually fired, verified scenarios
    // were coverage-checked, and somebody got preempted somewhere.
    assert!(completed >= slice.len() * 4, "only {completed} requests completed");
    assert!(cancelled > 0, "no cancel-storm scenario actually cancelled");
    assert!(failed_requests > 0, "no backend-error scenario actually failed a request");
    assert!(preemptions > 0, "no scenario preempted");
    assert!(coverage_checked > 0, "no verified scenario ran a coverage check");
}
