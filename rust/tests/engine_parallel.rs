//! Determinism and paging behavior of the parallel continuous-batching
//! engine: token streams must be byte-identical across worker counts and
//! prefill chunk sizes (dense and sparse, greedy and stochastic
//! sampling), KV capacity must gate admission without changing outputs,
//! and the open-loop trace mode must serve every request. The streaming
//! `Session` is held to the same bar: its interleaved `Token` event
//! streams must be byte-identical to `Engine::serve` output at any
//! worker count, and mid-stream cancellation must leak nothing.

use std::collections::BTreeMap;

use vattn::model::{Model, ModelConfig, Sampler};
use vattn::policies::{SizeSpec, VAttentionConfig};
use vattn::server::{
    AttentionMode, Engine, EngineConfig, EngineError, Event, GenOptions, Request, Session,
    SubmitRequest,
};
use vattn::workloads::traces::{generate_trace, to_requests, TraceConfig};
use vattn::util::Rng;

fn reqs(n: usize) -> Vec<Request> {
    (0..n as u64)
        .map(|i| {
            let plen = 8 + 5 * (i as usize % 4); // mixed prompt lengths
            let glen = 3 + (i as usize % 3); // mixed generation lengths
            let prompt: Vec<u32> = (0..plen as u32).map(|t| (t * 13 + i as u32) % 250).collect();
            Request::new(i, prompt, glen)
        })
        .collect()
}

fn sparse_mode() -> AttentionMode {
    AttentionMode::Sparse(Box::new(|_l, _h| {
        let mut c = vattn::policies::VAttentionConfig::default();
        c.sink = SizeSpec::Abs(4);
        c.window = SizeSpec::Abs(8);
        c.heavy = SizeSpec::Frac(0.05);
        c.verify = vattn::budget::Verify::Denominator;
        c.eps = 0.2;
        c.delta = 0.2;
        Box::new(vattn::policies::VAttentionPolicy::oracle(c))
    }))
}

fn streams(
    workers: usize,
    prefill_chunk: usize,
    sampler: Sampler,
    mode: &AttentionMode,
) -> Vec<(u64, Vec<u32>)> {
    let eng = Engine::new(
        Model::new(ModelConfig::tiny(), 42),
        EngineConfig {
            max_batch: 3,
            sampler,
            seed: 7,
            workers,
            prefill_chunk,
            ..Default::default()
        },
    );
    eng.serve(reqs(9), mode)
        .unwrap()
        .into_iter()
        .map(|r| (r.id, r.tokens))
        .collect()
}

#[test]
fn dense_tokens_identical_across_worker_counts() {
    let base = streams(1, 32, Sampler::Greedy, &AttentionMode::Dense);
    for workers in [2usize, 4, 8] {
        let got = streams(workers, 32, Sampler::Greedy, &AttentionMode::Dense);
        assert_eq!(base, got, "workers={workers} diverged from sequential run");
    }
}

#[test]
fn sparse_tokens_identical_across_worker_counts() {
    // Sparse decoding draws from per-request RNGs inside worker threads;
    // the streams must still match the single-worker run exactly.
    let base = streams(1, 32, Sampler::Greedy, &sparse_mode());
    let par = streams(4, 32, Sampler::Greedy, &sparse_mode());
    assert_eq!(base, par);
}

#[test]
fn stochastic_sampling_identical_across_worker_counts() {
    let base = streams(1, 32, Sampler::Temperature(0.8), &AttentionMode::Dense);
    let par = streams(4, 32, Sampler::Temperature(0.8), &AttentionMode::Dense);
    assert_eq!(base, par);
}

#[test]
fn prefill_chunking_does_not_change_tokens() {
    let one = streams(2, 1, Sampler::Greedy, &AttentionMode::Dense);
    let big = streams(2, 64, Sampler::Greedy, &AttentionMode::Dense);
    assert_eq!(one, big);
}

#[test]
fn kv_capacity_gates_admission_but_serves_everything() {
    let cfg = ModelConfig::tiny();
    let mk = |cap_bytes: Option<usize>| {
        Engine::new(
            Model::new(cfg.clone(), 42),
            EngineConfig {
                max_batch: 4,
                seed: 7,
                workers: 2,
                block_tokens: 16,
                kv_capacity_bytes: cap_bytes,
                ..Default::default()
            },
        )
    };
    // Every request needs 1 block (≤ 16 tokens); cap the pool at 2.
    let capped = mk(Some(2 * 16 * cfg.kv_bytes_per_token()));
    let unbounded = mk(None);
    let a = capped.serve(reqs(6), &AttentionMode::Dense).unwrap();
    let b = unbounded.serve(reqs(6), &AttentionMode::Dense).unwrap();
    assert_eq!(a.len(), 6);
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.tokens, y.tokens, "capacity gating changed request {}", x.id);
    }
}

/// Drive a `Session` tick-by-tick over the same workload as `streams`
/// and collect each request's `Token` event stream.
fn session_streams(workers: usize) -> Vec<(u64, Vec<u32>)> {
    let cfg = EngineConfig::builder()
        .max_batch(3)
        .seed(7)
        .workers(workers)
        .prefill_chunk(32)
        .build();
    let mut session = Session::new(Model::new(ModelConfig::tiny(), 42), cfg);
    let mut streams: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
    for r in reqs(9) {
        let id = session
            .submit(SubmitRequest::new(r.prompt).options(GenOptions::new(r.gen_len).seed(r.id)));
        streams.insert(id, Vec::new());
    }
    while !session.is_idle() {
        for ev in session.tick().expect("tick") {
            match ev {
                Event::Token { id, token, step, .. } => {
                    let s = streams.get_mut(&id).expect("token for known request");
                    assert_eq!(s.len(), step, "step indices must be gapless and in order");
                    s.push(token);
                }
                Event::Finished { id, result, .. } => {
                    assert_eq!(
                        result.tokens, streams[&id],
                        "Token events must replay the final stream exactly"
                    );
                }
                Event::Admitted { .. } => {}
                Event::Preempted { .. } => panic!("unbounded pool must not preempt"),
                Event::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
            }
        }
    }
    streams.into_iter().collect()
}

#[test]
fn session_token_events_match_serve_at_any_worker_count() {
    // The acceptance bar for the streaming redesign: the event-driven
    // session, ticked by hand, emits per-request token streams that are
    // byte-identical to the batch `Engine::serve` output — sequentially
    // and with a worker pool.
    let batch = streams(1, 32, Sampler::Greedy, &AttentionMode::Dense);
    for workers in [1usize, 4] {
        let streamed = session_streams(workers);
        assert_eq!(batch, streamed, "session(workers={workers}) diverged from Engine::serve");
    }
}

#[test]
fn per_request_guarantees_and_midstream_cancellation() {
    // One batch, three contracts: a dense request, a verified request
    // with its own (ε, δ), and a long dense request cancelled
    // mid-stream. Cancellation must return every leased KV block.
    let cfg = EngineConfig::builder().max_batch(3).seed(7).workers(2).block_tokens(16).build();
    let mut session = Session::new(Model::new(ModelConfig::tiny(), 42), cfg);
    let long_prompt: Vec<u32> = (0..192u32).map(|t| (t * 13) % 250).collect();

    let dense = session
        .submit(SubmitRequest::new(long_prompt.clone()).options(GenOptions::new(8)));
    let vcfg = VAttentionConfig {
        sink: SizeSpec::Abs(4),
        window: SizeSpec::Abs(8),
        heavy: SizeSpec::Frac(0.05),
        verify: vattn::budget::Verify::Denominator,
        ..Default::default()
    }
    .with_guarantee(0.2, 0.2);
    let verified = session.submit(
        SubmitRequest::new(long_prompt.clone()).options(GenOptions::new(8).verified_with(vcfg)),
    );
    let doomed =
        session.submit(SubmitRequest::new(long_prompt).options(GenOptions::new(64)));

    let mut doomed_tokens = 0usize;
    let mut results = BTreeMap::new();
    let mut cancelled = false;
    while !session.is_idle() {
        for ev in session.tick().expect("tick") {
            match ev {
                Event::Token { id, .. } if id == doomed => doomed_tokens += 1,
                Event::Finished { id, result, .. } => {
                    results.insert(id, result);
                }
                _ => {}
            }
        }
        if !cancelled && doomed_tokens >= 2 {
            let before = session.kv_blocks_in_use();
            session.cancel(doomed).expect("cancel active request");
            assert!(
                session.kv_blocks_in_use() < before,
                "cancellation must return the request's KV blocks immediately"
            );
            assert!(matches!(
                session.cancel(doomed),
                Err(EngineError::UnknownRequest(_))
            ));
            cancelled = true;
        }
    }
    assert!(cancelled, "the long request must have streamed tokens before finishing");
    assert_eq!(session.kv_blocks_in_use(), 0, "drained session must hold zero blocks");
    assert!(!results.contains_key(&doomed), "cancelled request must not finish");
    // Per-request contracts held within one batch: dense neighbor reads
    // everything, the verified one genuinely sparsifies.
    assert!((results[&dense].mean_density - 1.0).abs() < 1e-9);
    assert!(results[&verified].mean_density < 1.0);
    assert!(results[&verified].kv_bytes_read < results[&dense].kv_bytes_read);
    assert_eq!(results[&dense].tokens.len(), 8);
    assert_eq!(results[&verified].tokens.len(), 8);
}

#[test]
fn open_loop_trace_serves_all_requests() {
    let cfg = ModelConfig::tiny();
    let trace_cfg = TraceConfig {
        rate: 200.0, // fast arrivals so the test stays quick
        num_requests: 10,
        context_min: 8,
        context_max: 32,
        gen_min: 2,
        gen_max: 5,
    };
    let mut rng = Rng::new(11);
    let trace = generate_trace(&trace_cfg, &mut rng);
    let requests = to_requests(&trace, cfg.vocab);
    let want: Vec<(u64, usize)> = requests.iter().map(|r| (r.req.id, r.req.gen_len)).collect();
    let eng = Engine::new(
        Model::new(cfg, 42),
        EngineConfig { max_batch: 3, workers: 2, ..Default::default() },
    );
    let out = eng.serve_open_loop(requests, &AttentionMode::Dense).unwrap();
    assert_eq!(out.len(), 10);
    for (r, (id, glen)) in out.iter().zip(want.iter()) {
        assert_eq!(r.id, *id, "results sorted by id");
        assert_eq!(r.tokens.len(), *glen);
        assert!(r.wait_s >= 0.0);
        assert!(r.ttft_from_arrival_s() >= r.ttft_s);
    }
}
