//! Property-based tests over coordinator and estimator invariants, using
//! the in-repo helper (`util::proptest`). Each property runs across many
//! seeded random cases; failures report the reproducing seed.

use vattn::attention::{dense_sdpa, sparse_sdpa, Selection};
use vattn::budget::{budget_denominator, budget_numerator, BaseStats, Bound};
use vattn::kvcache::{BlockId, BlockPool, KvCache, KvDtype, PageError};
use vattn::model::{Model, ModelConfig};
use vattn::policies::*;
use vattn::server::{
    AttentionMode, Engine, EngineConfig, EngineError, Event, GenOptions, Request, Session,
    SubmitRequest,
};
use vattn::tensor::{rel_l2_error, Mat};
use vattn::util::json::Json;
use vattn::util::proptest::Prop;
use vattn::util::Rng;

fn random_head(rng: &mut Rng, n: usize, d: usize) -> (Mat, Mat, Vec<f32>) {
    let k = Mat::randn(n, d, 1.0, rng);
    let v = Mat::randn(n, d, 1.0, rng);
    let q: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0) / (d as f32).sqrt()).collect();
    (k, v, q)
}

#[test]
fn prop_selections_always_valid() {
    Prop::new("selections-valid").cases(60).run(|rng| {
        let n = rng.range(64, 2048);
        let d = [16, 32, 48][rng.below(3)];
        let (k, v, q) = random_head(rng, n, d);
        let methods = [
            "oracle-top-k",
            "random-sample",
            "hybrid",
            "hashattention",
            "quest",
            "magicpig",
            "vattention-oracle",
        ];
        let m = methods[rng.below(methods.len())];
        let knobs = vattn::experiments::common::knob_sweep(m);
        let knob = knobs[rng.below(knobs.len())];
        let mut pol = vattn::experiments::common::make_policy(m, knob, rng.next_u64());
        let mut fork = rng.fork(1);
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut fork, step: 0 };
        let sel = pol.select(&mut ctx);
        if let Err(e) = sel.validate(n) {
            panic!("{m} (n={n}, knob={knob}): {e}");
        }
    });
}

#[test]
fn prop_sparse_converges_to_dense_as_density_to_one() {
    Prop::new("density-1-equals-dense").cases(40).run(|rng| {
        let n = rng.range(32, 512);
        let d = 16;
        let (k, v, q) = random_head(rng, n, d);
        let dense = dense_sdpa(&k, &v, &q).out;
        let sel = Selection::deterministic((0..n).collect());
        let sparse = sparse_sdpa(&k, &v, &q, &sel);
        let err = rel_l2_error(&sparse, &dense);
        assert!(err < 1e-5, "full selection err {err} (n={n})");
    });
}

#[test]
fn prop_budget_monotone_in_tolerance() {
    Prop::new("budget-monotone").cases(100).run(|rng| {
        let stats = BaseStats {
            n_s: rng.range(100, 100_000),
            sigma2_d: rng.f64() * 4.0 + 1e-6,
            trace_sigma_n: rng.f64() * 100.0 + 1e-6,
            d_hat: rng.f64() * 1e4 + 10.0,
            n_hat_norm: rng.f64() * 1e4 + 10.0,
            range_d: rng.f64() * 10.0 + 0.1,
            range_n: rng.f64() * 30.0 + 0.1,
            base_size: 128,
        };
        let bound = if rng.below(2) == 0 { Bound::Clt } else { Bound::Hoeffding };
        let eps_lo = 0.01 + rng.f64() * 0.1;
        let eps_hi = eps_lo * (1.5 + rng.f64());
        let delta = 0.05 + rng.f64() * 0.3;
        let b_tight = budget_denominator(&stats, eps_lo, delta, bound);
        let b_loose = budget_denominator(&stats, eps_hi, delta, bound);
        assert!(b_tight >= b_loose, "D: eps {eps_lo}<{eps_hi} but {b_tight}<{b_loose}");
        let b_tight = budget_numerator(&stats, eps_lo, delta, bound);
        let b_loose = budget_numerator(&stats, eps_hi, delta, bound);
        assert!(b_tight >= b_loose, "N: eps monotonicity violated");
    });
}

#[test]
fn prop_estimator_unbiased_over_resampling() {
    // For any head, averaging the importance-weighted denominator over
    // many resamples approaches the exact denominator.
    Prop::new("estimator-unbiased").cases(8).run(|rng| {
        let n = rng.range(200, 800);
        let (k, v, q) = random_head(rng, n, 16);
        let m_ref = 0.0f32;
        let (_, d_exact) = vattn::attention::exact_num_den(&k, &v, &q, m_ref);
        let b = (n / 4).max(10);
        let mut acc = 0.0f64;
        let resamples = 800;
        for t in 0..resamples {
            let mut fork = rng.fork(t as u64);
            let idx = fork.sample_distinct(n, b);
            let sel = Selection::sampled(idx, b as f32 / n as f32);
            let (_, d_hat) = vattn::attention::weighted_num_den(&k, &v, &q, &sel, m_ref);
            acc += d_hat;
        }
        let rel = (acc / resamples as f64 - d_exact).abs() / d_exact;
        assert!(rel < 0.05, "bias {rel} (n={n}, b={b})");
    });
}

#[test]
fn prop_engine_serves_every_request_exactly_once() {
    Prop::new("engine-complete-fifo").cases(12).run(|rng| {
        let n_req = rng.range(1, 12);
        let max_batch = rng.range(1, 5);
        let eng = Engine::new(
            Model::new(ModelConfig::tiny(), 42),
            EngineConfig { max_batch, ..Default::default() },
        );
        let reqs: Vec<Request> = (0..n_req as u64)
            .map(|i| {
                let plen = rng.range(1, 24);
                let glen = rng.range(1, 8);
                Request::new(i, (0..plen as u32).collect(), glen)
            })
            .collect();
        let want: Vec<(u64, usize)> = reqs.iter().map(|r| (r.id, r.gen_len)).collect();
        let out = eng.serve(reqs, &AttentionMode::Dense).unwrap();
        assert_eq!(out.len(), n_req, "request count");
        for (r, (id, glen)) in out.iter().zip(want.iter()) {
            assert_eq!(r.id, *id, "ids sorted/unique");
            assert_eq!(r.tokens.len(), *glen, "generation length");
        }
    });
}

#[test]
fn prop_vattention_density_never_exceeds_one_and_respects_floor() {
    Prop::new("vattention-density-bounds").cases(30).run(|rng| {
        let n = rng.range(300, 4000);
        let (k, v, q) = random_head(rng, n, 16);
        let mut cfg = vattn::experiments::common::vcfg(0.01 + rng.f64() * 0.4);
        cfg.sink = SizeSpec::Abs(rng.range(0, 64));
        cfg.window = SizeSpec::Abs(rng.range(0, 64));
        cfg.heavy = SizeSpec::Frac(rng.f64() * 0.2);
        cfg.base_rate = 0.01 + rng.f64() * 0.1;
        let mut pol = VAttentionPolicy::oracle(cfg);
        let mut fork = rng.fork(2);
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut fork, step: 0 };
        let sel = pol.select(&mut ctx);
        sel.validate(n).expect("valid");
        let dec = pol.last.as_ref().unwrap();
        assert!(dec.budget <= dec.n_s);
        assert!(sel.len() == dec.n_fixed + dec.budget);
        assert!(sel.density(n) <= 1.0 + 1e-9);
    });
}

#[test]
fn prop_block_pool_invariants_under_random_alloc_free() {
    // Random alloc/free sequences against a model of the pool: ids held
    // out are unique, capacity is never exceeded, refusals only happen
    // when the lease truly would not fit, double frees always error, and
    // the byte accounting tracks the held set exactly.
    Prop::new("block-pool-invariants").cases(40).run(|rng| {
        let cap = rng.range(4, 64);
        let block_bytes = 256 * rng.range(1, 8);
        let mut pool = BlockPool::new(16, block_bytes, Some(cap));
        let mut held: Vec<Vec<BlockId>> = Vec::new();
        for _ in 0..150 {
            if rng.below(2) == 0 || held.is_empty() {
                let n = rng.range(1, 6);
                let in_use_before = pool.in_use_blocks();
                match pool.try_alloc(n) {
                    Some(ids) => {
                        assert_eq!(ids.len(), n);
                        let mut all: std::collections::HashSet<BlockId> =
                            held.iter().flatten().copied().collect();
                        for &id in &ids {
                            assert!(all.insert(id), "pool leased live block {id} twice");
                        }
                        held.push(ids);
                    }
                    None => {
                        assert!(in_use_before + n > cap, "refused a lease that fit");
                        assert_eq!(pool.in_use_blocks(), in_use_before, "refusal leaked");
                    }
                }
            } else {
                let i = rng.below(held.len());
                let ids = held.swap_remove(i);
                pool.free(ids.iter().copied()).expect("legal free");
                // the same ids are now stale: freeing again must error
                assert!(matches!(
                    pool.free([ids[0]]),
                    Err(PageError::DoubleFree(_))
                ));
            }
            let held_count: usize = held.iter().map(|v| v.len()).sum();
            assert_eq!(pool.in_use_blocks(), held_count);
            assert!(pool.in_use_blocks() <= cap);
            assert_eq!(pool.bytes_in_use(), held_count * block_bytes);
        }
    });
}

#[test]
fn prop_block_pool_reuses_before_minting() {
    // After any free, subsequent leases must drain the free list before
    // new ids are minted: minted_blocks never exceeds the high-water mark
    // of concurrently held blocks.
    Prop::new("block-pool-reuse").cases(40).run(|rng| {
        let mut pool = BlockPool::new(8, 128, None);
        let mut held: Vec<Vec<BlockId>> = Vec::new();
        let mut peak_held = 0usize;
        for _ in 0..120 {
            if rng.below(2) == 0 || held.is_empty() {
                let n = rng.range(1, 5);
                held.push(pool.try_alloc(n).expect("unbounded pool"));
                let cur: usize = held.iter().map(|v| v.len()).sum();
                peak_held = peak_held.max(cur);
            } else {
                let i = rng.below(held.len());
                pool.free(held.swap_remove(i)).expect("legal free");
            }
            assert!(
                pool.minted_blocks() <= peak_held,
                "minted {} > peak concurrent {} — free list not reused",
                pool.minted_blocks(),
                peak_held
            );
        }
    });
}

#[test]
fn prop_block_pool_refcount_fork_cow_free_interleavings() {
    // Random interleavings of alloc / retain (fork) / cow / free against
    // a reference model of per-block refcounts: a block is freed exactly
    // once (when its count hits zero — later frees are DoubleFree, never
    // silent), sharing never costs capacity, cow detaches exactly one
    // reference, and the pool ends quiescent once the model drains.
    use std::collections::HashMap;
    use vattn::kvcache::CowOutcome;
    Prop::new("block-pool-refcounts").cases(40).run(|rng| {
        let cap = rng.range(4, 32);
        let mut pool = BlockPool::new(16, 512, Some(cap));
        // Model: live block id -> expected refcount.
        let mut model: HashMap<BlockId, u32> = HashMap::new();
        let pick = |model: &HashMap<BlockId, u32>, rng: &mut Rng| -> BlockId {
            let mut ids: Vec<BlockId> = model.keys().copied().collect();
            ids.sort_unstable();
            ids[rng.below(ids.len())]
        };
        for _ in 0..200 {
            match rng.below(4) {
                0 => {
                    let n = rng.range(1, 4);
                    match pool.try_alloc(n) {
                        Some(ids) => {
                            assert_eq!(ids.len(), n);
                            for id in ids {
                                assert!(
                                    model.insert(id, 1).is_none(),
                                    "pool leased live block {id} twice"
                                );
                            }
                        }
                        None => assert!(model.len() + n > cap, "refused a lease that fit"),
                    }
                }
                1 if !model.is_empty() => {
                    let id = pick(&model, rng);
                    pool.retain(id).expect("retain of live block");
                    *model.get_mut(&id).unwrap() += 1;
                }
                2 if !model.is_empty() => {
                    let id = pick(&model, rng);
                    let refs = model[&id];
                    match pool.cow(id).expect("cow of live block") {
                        CowOutcome::InPlace => {
                            assert_eq!(refs, 1, "in-place write requires sole ownership")
                        }
                        CowOutcome::Copied(fresh) => {
                            assert!(refs > 1, "copy implies the block was shared");
                            *model.get_mut(&id).unwrap() -= 1;
                            assert!(model.insert(fresh, 1).is_none(), "cow reused a live id");
                        }
                        CowOutcome::OutOfBlocks => {
                            assert!(refs > 1 && model.len() + 1 > cap, "spurious exhaustion");
                        }
                    }
                }
                _ if !model.is_empty() => {
                    let id = pick(&model, rng);
                    pool.free([id]).expect("free of live block");
                    let r = model.get_mut(&id).unwrap();
                    *r -= 1;
                    if *r == 0 {
                        model.remove(&id);
                        // The id is dead: another free must error, not
                        // double-release.
                        assert!(matches!(pool.free([id]), Err(PageError::DoubleFree(_))));
                        assert!(matches!(pool.retain(id), Err(PageError::DoubleFree(_))));
                    }
                }
                _ => {}
            }
            assert_eq!(pool.in_use_blocks(), model.len(), "resident-block accounting drifted");
            assert!(pool.in_use_blocks() <= cap);
            for (&id, &refs) in &model {
                assert_eq!(pool.ref_count(id), refs, "refcount of block {id} drifted");
            }
        }
        // Drain: every reference released exactly once ⇒ quiescent.
        let mut ids: Vec<(BlockId, u32)> = model.into_iter().collect();
        ids.sort_unstable();
        for (id, refs) in ids {
            for _ in 0..refs {
                pool.free([id]).expect("draining free");
            }
        }
        assert!(pool.is_quiescent(), "drained pool must be quiescent");
    });
}

#[test]
fn prop_paged_cache_accounting_consistent() {
    // Appends into a paged cache: token/block accounting agrees with the
    // reservation, gather charges exactly the gathered bytes, and
    // release returns every leased block to the pool.
    Prop::new("paged-cache-accounting").cases(25).run(|rng| {
        let cfg = ModelConfig::tiny();
        let block_tokens = [4usize, 8, 16][rng.below(3)];
        let mut pool = BlockPool::for_model(&cfg, block_tokens, None);
        let total_tokens = rng.range(1, 40);
        let lease = pool.try_alloc(pool.blocks_for_tokens(total_tokens)).unwrap();
        let reserved = lease.len();
        let mut cache = KvCache::paged(&cfg, block_tokens, lease);
        let row = vec![0.5f32; cfg.d_head()];
        let tokens = rng.range(1, total_tokens + 1);
        for _ in 0..tokens {
            for l in 0..cfg.n_layers {
                for h in 0..cfg.n_kv_heads {
                    cache.append(l, h, &row, &row);
                }
            }
        }
        assert_eq!(cache.tokens(), tokens);
        assert_eq!(cache.blocks_used(), tokens.div_ceil(block_tokens));
        assert!(cache.blocks_used() <= cache.blocks_reserved());
        assert_eq!(cache.blocks_reserved(), reserved);

        let before = cache.stats.bytes_read;
        let m = rng.range(1, tokens + 1);
        let idx: Vec<usize> = (0..m).collect();
        let (gk, gv) = cache.gather(0, 0, &idx);
        assert_eq!(gk.rows, m);
        assert_eq!(gv.rows, m);
        assert_eq!(cache.stats.bytes_read - before, 2 * m * cfg.d_head() * 4);

        let freed = cache.release_blocks();
        assert_eq!(freed.len(), reserved);
        assert_eq!(cache.tokens(), 0);
        pool.free(freed).expect("release then free");
        assert_eq!(pool.in_use_blocks(), 0);
    });
}

#[test]
fn prop_session_submit_cancel_interleaving_leaks_no_blocks() {
    // Random interleavings of submit / cancel / tick against a
    // capacity-bounded session: leased blocks never exceed the pool cap,
    // cancelling a live request always succeeds exactly once (the second
    // attempt is `UnknownRequest`, never a pool double-free), and a
    // drained session holds zero blocks.
    Prop::new("session-cancel-no-leak").cases(10).run(|rng| {
        let mcfg = ModelConfig::tiny();
        let cap_blocks = rng.range(2, 6);
        let cfg = EngineConfig::builder()
            .max_batch(rng.range(1, 4))
            .seed(rng.next_u64())
            .block_tokens(16)
            .kv_capacity_bytes(cap_blocks * 16 * mcfg.kv_bytes_per_token())
            .build();
        let mut session = Session::new(Model::new(mcfg, 42), cfg);
        // Requests stay ≤ 2 blocks (≤ cap) so none is ever rejected.
        let mut live: Vec<u64> = Vec::new();
        for _ in 0..60 {
            match rng.below(4) {
                0 => {
                    let plen = rng.range(1, 20);
                    let glen = rng.range(1, 6);
                    let prompt: Vec<u32> = (0..plen as u32).map(|t| t % 250).collect();
                    let id = session
                        .submit(SubmitRequest::new(prompt).options(GenOptions::new(glen)));
                    live.push(id);
                }
                1 if !live.is_empty() => {
                    let id = live.swap_remove(rng.below(live.len()));
                    session.cancel(id).expect("cancelling a live request must succeed");
                    assert!(
                        matches!(session.cancel(id), Err(EngineError::UnknownRequest(_))),
                        "second cancel must be UnknownRequest, not a double free"
                    );
                }
                _ => {
                    for ev in session.tick().expect("tick must not hit pool errors") {
                        if let Event::Finished { id, result, .. } = ev {
                            assert!(live.contains(&id), "finished request must be live");
                            assert!(!result.tokens.is_empty());
                            live.retain(|&x| x != id);
                        }
                    }
                }
            }
            assert!(
                session.kv_blocks_in_use() <= cap_blocks,
                "leases exceeded pool capacity"
            );
            assert_eq!(
                session.outstanding(),
                live.len(),
                "session and model of live requests diverged"
            );
        }
        // Cancel whatever is still in flight, then verify quiescence.
        for id in live.drain(..) {
            session.cancel(id).expect("cancelling a live request must succeed");
        }
        assert!(session.is_idle());
        assert_eq!(session.kv_blocks_in_use(), 0, "drained session leaked blocks");
    });
}

#[test]
fn prop_spill_mode_is_stream_invisible_and_leak_free() {
    // The cold tier's contract, fuzzed: a contended session that spills
    // preempted KV to disk must emit token streams byte-identical to an
    // uncontended spill-off run of the same workload — with zero replay
    // preemptions, every spilled byte swapped back in exactly once, and
    // no pool blocks or cold-tier slots left behind after drain,
    // mid-flight cancellations included.
    Prop::new("spill-stream-invisible").cases(8).run(|rng| {
        use std::collections::BTreeMap;
        let mcfg = ModelConfig::tiny();
        let bt = 4usize;
        // Worst case per request is 8 blocks (19 + 11 tokens), so every
        // request is admissible alone but two together can contend.
        let cap_blocks = rng.range(8, 12);
        let engine_seed = rng.next_u64();
        let n_req = rng.range(2, 6);
        let reqs: Vec<(Vec<u32>, usize)> = (0..n_req)
            .map(|_| {
                let plen = rng.range(4, 20);
                let glen = rng.range(4, 12);
                ((0..plen as u32).map(|t| (t * 7 + 3) % 250).collect(), glen)
            })
            .collect();
        let path = std::env::temp_dir()
            .join(format!("vattn-prop-spill-{}-{engine_seed:x}.spill", std::process::id()));
        let _ = std::fs::remove_file(&path);

        let drive = |mut session: Session<Model>| -> (BTreeMap<u64, Vec<u32>>, Session<Model>) {
            let mut streams: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
            for (prompt, glen) in &reqs {
                let id = session
                    .submit(SubmitRequest::new(prompt.clone()).options(GenOptions::new(*glen)));
                streams.insert(id, Vec::new());
            }
            while !session.is_idle() {
                for ev in session.tick().expect("tick") {
                    if let Event::Token { id, token, step, .. } = ev {
                        let st = streams.get_mut(&id).expect("known id");
                        assert_eq!(st.len(), step, "gapless stream across swap-in");
                        st.push(token);
                    }
                }
            }
            (streams, session)
        };

        let free_cfg =
            EngineConfig::builder().max_batch(3).seed(engine_seed).block_tokens(bt).build();
        let (reference, _) = drive(Session::new(Model::new(mcfg.clone(), 42), free_cfg));

        let spill_cfg = EngineConfig::builder()
            .max_batch(3)
            .seed(engine_seed)
            .block_tokens(bt)
            .kv_capacity_bytes(cap_blocks * bt * mcfg.kv_bytes_per_token())
            .kv_spill(&path)
            .build();
        let (spilled, mut session) = drive(Session::new(Model::new(mcfg.clone(), 42), spill_cfg));
        assert_eq!(reference, spilled, "the cold tier changed a token stream");
        let stats = session.stats();
        assert_eq!(stats.preemption_replays, 0, "spill mode must never replay");
        assert_eq!(stats.swap_in_bytes, stats.spill_out_bytes, "unbalanced swap traffic");
        assert_eq!(stats.swap_in_ops, stats.spill_out_ops);
        assert_eq!(session.spill_live_blocks(), Some(0), "orphaned cold-tier blocks");
        assert_eq!(session.kv_blocks_in_use(), 0, "drained session leaked pool blocks");

        // Mid-flight cancellation: whatever state a request is in —
        // active, suspended on disk, or still queued — cancelling it
        // must release both its pool lease and its cold-tier slots.
        let mut live: Vec<u64> = reqs
            .iter()
            .map(|(p, g)| {
                session.submit(SubmitRequest::new(p.clone()).options(GenOptions::new(*g)))
            })
            .collect();
        for _ in 0..rng.range(0, 6) {
            for ev in session.tick().expect("tick") {
                if let Event::Finished { id, .. } = ev {
                    live.retain(|&x| x != id);
                }
            }
        }
        for id in live {
            session.cancel(id).expect("cancelling a live request");
        }
        assert!(session.is_idle());
        assert_eq!(session.spill_live_blocks(), Some(0), "cancel leaked cold-tier slots");
        assert_eq!(session.kv_blocks_in_use(), 0, "cancel leaked pool blocks");
        let _ = std::fs::remove_file(&path);
    });
}

#[test]
fn prop_prefetch_pipeline_is_schedule_invisible_under_interleavings() {
    // The async-prefetch contract, fuzzed: random interleavings of
    // submit / tick / cancel drive three engines off one shared
    // operation script — uncontended, contended + spill, and contended
    // + spill + prefetch. Prefetch only moves data, so the spill run
    // and the prefetch run must produce *identical* outcome maps
    // (streams and cancel points alike); completed streams must match
    // the uncontended reference byte-for-byte and cancelled ones must
    // be prefixes of it. Both contended sessions must drain to zero
    // pool blocks and zero live cold-tier slots, and the prefetch
    // ledger must conserve: every issued block is eventually consumed
    // or wasted, and every swap-in is either staged or blocking.
    Prop::new("prefetch-schedule-invisible").cases(8).run(|rng| {
        use std::collections::BTreeMap;
        #[derive(Clone, Copy, Debug)]
        enum Op {
            Submit(usize),
            Tick,
            Cancel(usize),
        }
        type Outcomes = BTreeMap<usize, (bool, Vec<u32>)>;

        let mcfg = ModelConfig::tiny();
        let bt = 4usize;
        // Worst case per request is 8 blocks (19 + 11 tokens): every
        // request is admissible alone, but two together can contend.
        let cap_blocks = rng.range(8, 12);
        let engine_seed = rng.next_u64();
        let n_req = rng.range(3, 6);
        let reqs: Vec<(Vec<u32>, GenOptions)> = (0..n_req)
            .map(|i| {
                let plen = rng.range(4, 20);
                let glen = rng.range(4, 12);
                // Mixed per-request dtypes exercise the dtype-aware
                // victim policy under prefetch.
                let opts = match i % 3 {
                    0 => GenOptions::new(glen),
                    1 => GenOptions::new(glen).kv_dtype(KvDtype::Int8),
                    _ => GenOptions::new(glen).kv_dtype(KvDtype::Int4),
                };
                ((0..plen as u32).map(|t| (t * 11 + 5) % 250).collect(), opts)
            })
            .collect();

        // One script drives every engine: submits in request order with
        // tick gaps, a tick tail, then cancels spliced in at random
        // points after their target's submit.
        let mut script: Vec<Op> = Vec::new();
        for i in 0..n_req {
            script.push(Op::Submit(i));
            for _ in 0..rng.below(3) {
                script.push(Op::Tick);
            }
        }
        for _ in 0..rng.range(2, 12) {
            script.push(Op::Tick);
        }
        for i in 0..n_req {
            if rng.below(3) == 0 {
                let submit_at = script
                    .iter()
                    .position(|op| matches!(op, Op::Submit(j) if *j == i))
                    .unwrap();
                let at = rng.range(submit_at + 1, script.len() + 1);
                script.insert(at, Op::Cancel(i));
            }
        }

        let drive = |mut session: Session<Model>, script: &[Op]| -> (Outcomes, Session<Model>) {
            let mut ids: Vec<Option<u64>> = vec![None; n_req];
            let mut streams: Vec<Vec<u32>> = vec![Vec::new(); n_req];
            let mut outcomes: Outcomes = BTreeMap::new();
            let pump = |session: &mut Session<Model>,
                        ids: &[Option<u64>],
                        streams: &mut [Vec<u32>],
                        outcomes: &mut Outcomes| {
                for ev in session.tick().expect("tick") {
                    match ev {
                        Event::Token { id, token, step, .. } => {
                            let i = ids.iter().position(|&x| x == Some(id)).expect("known id");
                            assert_eq!(streams[i].len(), step, "gapless stream across swap-in");
                            streams[i].push(token);
                        }
                        Event::Finished { id, .. } => {
                            let i = ids.iter().position(|&x| x == Some(id)).expect("known id");
                            outcomes.insert(i, (false, streams[i].clone()));
                        }
                        _ => {}
                    }
                }
            };
            for op in script {
                match *op {
                    Op::Submit(i) => {
                        let (prompt, opts) = &reqs[i];
                        ids[i] = Some(
                            session
                                .submit(SubmitRequest::new(prompt.clone()).options(opts.clone())),
                        );
                    }
                    Op::Tick => pump(&mut session, &ids, &mut streams, &mut outcomes),
                    Op::Cancel(i) => {
                        // The target may have finished already (the
                        // script is progress-agnostic); cancel only if
                        // it is still live.
                        if !outcomes.contains_key(&i) {
                            session
                                .cancel(ids[i].expect("cancel after submit"))
                                .expect("cancelling a live request must succeed");
                            outcomes.insert(i, (true, streams[i].clone()));
                        }
                    }
                }
            }
            let mut rounds = 0usize;
            while !session.is_idle() {
                rounds += 1;
                assert!(rounds <= 100_000, "drain did not converge");
                pump(&mut session, &ids, &mut streams, &mut outcomes);
            }
            (outcomes, session)
        };

        // Uncontended reference with the cancels stripped: full streams
        // to diff every other run against.
        let full_script: Vec<Op> =
            script.iter().copied().filter(|op| !matches!(op, Op::Cancel(_))).collect();
        let free_cfg =
            EngineConfig::builder().max_batch(3).seed(engine_seed).block_tokens(bt).build();
        let (reference, _) =
            drive(Session::new(Model::new(mcfg.clone(), 42), free_cfg), &full_script);
        for i in 0..n_req {
            assert!(matches!(reference.get(&i), Some((false, _))), "reference must complete");
        }

        let spill_cfg = |prefetch: bool, tag: &str| {
            let path = std::env::temp_dir().join(format!(
                "vattn-prop-prefetch-{}-{engine_seed:x}-{tag}.spill",
                std::process::id()
            ));
            let _ = std::fs::remove_file(&path);
            let cfg = EngineConfig::builder()
                .max_batch(3)
                .seed(engine_seed)
                .block_tokens(bt)
                .kv_capacity_bytes(cap_blocks * bt * mcfg.kv_bytes_per_token())
                .kv_spill(&path)
                .kv_prefetch(prefetch)
                .build();
            (cfg, path)
        };
        let (off_cfg, off_path) = spill_cfg(false, "off");
        let (off_out, off_sess) =
            drive(Session::new(Model::new(mcfg.clone(), 42), off_cfg), &script);
        let (on_cfg, on_path) = spill_cfg(true, "on");
        let (on_out, on_sess) = drive(Session::new(Model::new(mcfg.clone(), 42), on_cfg), &script);

        assert_eq!(off_out, on_out, "prefetch changed an outcome or a cancel point");
        for (i, (cancelled, stream)) in &on_out {
            let (_, full) = &reference[i];
            if *cancelled {
                assert!(
                    full.starts_with(stream),
                    "request {i}: cancelled stream is not a reference prefix"
                );
            } else {
                assert_eq!(stream, full, "request {i}: stream diverged from reference");
            }
        }

        for (name, sess) in [("off", &off_sess), ("on", &on_sess)] {
            let stats = sess.stats();
            assert_eq!(stats.preemption_replays, 0, "[{name}] spill mode must never replay");
            assert_eq!(stats.swap_in_bytes, stats.spill_out_bytes, "[{name}] unbalanced bytes");
            assert_eq!(stats.swap_in_ops, stats.spill_out_ops, "[{name}] unbalanced ops");
            assert_eq!(sess.spill_live_blocks(), Some(0), "[{name}] orphaned cold-tier slots");
            assert_eq!(sess.kv_blocks_in_use(), 0, "[{name}] leaked pool blocks");
            assert_eq!(
                stats.prefetch_hit_ops + stats.prefetch_wasted_ops,
                stats.prefetch_issued_ops,
                "[{name}] issued prefetch blocks neither consumed nor wasted"
            );
            assert_eq!(
                stats.blocking_swap_in_ops + stats.prefetch_hit_ops,
                stats.swap_in_ops,
                "[{name}] swap-ins neither staged nor blocking"
            );
        }
        let (off_stats, on_stats) = (off_sess.stats(), on_sess.stats());
        assert_eq!(
            off_stats.preemptions, on_stats.preemptions,
            "prefetch changed the preemption schedule"
        );
        assert_eq!(off_stats.spill_out_ops, on_stats.spill_out_ops);
        assert_eq!(off_stats.prefetch_issued_ops, 0, "prefetch-off engine issued prefetches");
        let _ = std::fs::remove_file(&off_path);
        let _ = std::fs::remove_file(&on_path);
    });
}

#[test]
fn prop_int8_roundtrip_respects_the_advertised_half_scale_bound() {
    // The quantized-KV tier's foundational contract: for every element
    // of every row — random, constant, zero, and max-magnitude alike —
    // |x − dequantize(quantize(x))| ≤ scale/2 with the row's advertised
    // scale. Exact (power-of-two scales), so no tolerance is added.
    use vattn::tensor::quant::QuantizedMat;
    Prop::new("int8-roundtrip-bound").cases(60).run(|rng| {
        let d = [8usize, 16, 31, 32, 64][rng.below(5)];
        let mut m = QuantizedMat::new(d);
        let mut rows: Vec<Vec<f32>> = Vec::new();
        let magnitude = [0.01f32, 1.0, 100.0, 1e30][rng.below(4)];
        for _ in 0..6 {
            rows.push((0..d).map(|_| rng.normal32(0.0, magnitude)).collect());
        }
        rows.push(vec![0.0; d]); // zero row
        let c = rng.normal32(0.0, magnitude);
        rows.push(vec![c; d]); // constant row
        let mut extreme = vec![f32::MAX; d]; // max-magnitude row
        extreme[d / 2] = -f32::MAX;
        rows.push(extreme);
        for row in &rows {
            m.push_row(row);
        }
        for (r, row) in rows.iter().enumerate() {
            let bound = m.max_abs_err(r);
            assert_eq!(bound, 0.5 * m.scale(r));
            let back = m.dequantize_row(r);
            for (c, (&x, &x_hat)) in row.iter().zip(back.iter()).enumerate() {
                assert!(x_hat.is_finite(), "row {r} col {c} dequantized to {x_hat}");
                assert!(
                    (x - x_hat).abs() <= bound,
                    "row {r} col {c}: |{x} − {x_hat}| > scale/2 = {bound}"
                );
            }
        }
    });
}

#[test]
fn prop_int8_quantization_is_deterministic() {
    // Same row ⇒ same bytes: codes and the scale's exact bit pattern.
    use vattn::tensor::quant::quantize_row_into;
    Prop::new("int8-deterministic").cases(80).run(|rng| {
        let d = rng.range(1, 96);
        let row: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 5.0)).collect();
        let mut a = Vec::new();
        let mut b = Vec::new();
        let sa = quantize_row_into(&row, &mut a);
        let sb = quantize_row_into(&row.clone(), &mut b);
        assert_eq!(a, b, "codes diverged for identical input");
        assert_eq!(sa.to_bits(), sb.to_bits(), "scales diverged for identical input");
    });
}

#[test]
fn prop_int8_fused_dequant_dot_is_bitwise_exact() {
    // The bridge lemma behind the dequantized working mirror: the fused
    // dequant-dot kernel equals dequantize-then-tensor::dot *bitwise*,
    // at every width (unrolled body + tail) and magnitude.
    use vattn::tensor::quant::QuantizedMat;
    Prop::new("int8-fused-dot-bitwise").cases(60).run(|rng| {
        let d = rng.range(1, 100);
        let mut m = QuantizedMat::new(d);
        let n_rows = rng.range(1, 8);
        for _ in 0..n_rows {
            let mag = [0.1f32, 1.0, 1000.0][rng.below(3)];
            let row: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, mag)).collect();
            m.push_row(&row);
        }
        let q: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0)).collect();
        for r in 0..n_rows {
            let fused = m.dot_row(r, &q);
            let two_step = vattn::tensor::dot(&m.dequantize_row(r), &q);
            assert_eq!(
                fused.to_bits(),
                two_step.to_bits(),
                "row {r} (d={d}): fused {fused} != dequantize-then-dot {two_step}"
            );
        }
    });
}

#[test]
fn prop_simd_dot_matches_scalar_oracle_bitwise() {
    // Kernel-equivalence gate: the dispatched dot (lane-array or AVX2,
    // fixed per process) must equal the scalar 8-wide oracle *bitwise*
    // at every width — full lane bodies, ragged tails, and the empty
    // slice alike — and at every magnitude.
    use vattn::tensor::simd;
    Prop::new("simd-dot-oracle-bitwise").cases(120).run(|rng| {
        let d = if rng.below(3) == 0 {
            [0usize, 1, 7, 8, 9, 15, 16, 17, 23, 24, 31, 32][rng.below(12)]
        } else {
            rng.range(1, 200)
        };
        let mag = [0.01f32, 1.0, 1e6][rng.below(3)];
        let a: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, mag)).collect();
        let b: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0)).collect();
        let fast = simd::dot(&a, &b);
        let oracle = simd::dot_oracle(&a, &b);
        assert_eq!(
            fast.to_bits(),
            oracle.to_bits(),
            "d={d} ({}): dispatched {fast} != oracle {oracle}",
            simd::kernel_name()
        );
    });
}

#[test]
fn prop_simd_fused_int8_dot_row_equals_unpack_then_dot_bitwise() {
    // Bridge lemma at the dispatched-kernel layer: the fused int8
    // dequant-dot shares the SIMD dot's accumulation order, so fused ≡
    // dequantize-then-simd::dot stays bitwise at every width.
    use vattn::tensor::quant::QuantizedMat;
    use vattn::tensor::simd;
    Prop::new("simd-int8-fused-bitwise").cases(80).run(|rng| {
        let d = rng.range(1, 150);
        let mut m = QuantizedMat::new(d);
        let mag = [0.1f32, 1.0, 1e4][rng.below(3)];
        let row: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, mag)).collect();
        m.push_row(&row);
        let q: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0)).collect();
        let fused = simd::dot_i8(m.row_codes(0), m.scale(0), &q);
        let two_step = simd::dot(&m.dequantize_row(0), &q);
        assert_eq!(fused.to_bits(), two_step.to_bits(), "d={d}");
    });
}

#[test]
fn prop_simd_fused_int4_dot_row_equals_unpack_then_dot_bitwise() {
    // Same bridge lemma for the bit-packed codec: in-register nibble
    // unpacking must not change a single bit vs dequantize-then-dot —
    // odd widths exercise the half-filled trailing byte.
    use vattn::tensor::quant::QuantizedMat4;
    use vattn::tensor::simd;
    Prop::new("simd-int4-fused-bitwise").cases(80).run(|rng| {
        let d = rng.range(1, 150);
        let mut m = QuantizedMat4::new(d);
        let n_rows = rng.range(1, 5);
        for _ in 0..n_rows {
            let mag = [0.1f32, 1.0, 1e4][rng.below(3)];
            let row: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, mag)).collect();
            m.push_row(&row);
        }
        let q: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0)).collect();
        for r in 0..n_rows {
            let fused = m.dot_row(r, &q);
            let two_step = simd::dot(&m.dequantize_row(r), &q);
            assert_eq!(
                fused.to_bits(),
                two_step.to_bits(),
                "row {r} (d={d}): fused {fused} != dequantize-then-dot {two_step}"
            );
        }
    });
}

#[test]
fn prop_simd_weighted_moments_matches_sequential_reference_bitwise() {
    // The budget stats pass is column-parallel (each column's f64
    // accumulator sees the same op sequence either way) and the rn2
    // reduction is kept sequential — so the kernel must agree with the
    // naive interleaved loop bitwise, on every accumulator.
    use vattn::tensor::simd;
    Prop::new("simd-weighted-moments-bitwise").cases(80).run(|rng| {
        let d = rng.range(1, 60);
        let rows = rng.range(1, 20);
        let mut sv_a = vec![0.0f64; d];
        let mut sv2_a = vec![0.0f64; d];
        let mut sv_b = vec![0.0f64; d];
        let mut sv2_b = vec![0.0f64; d];
        for _ in 0..rows {
            let w = rng.f64() * 3.0;
            let row: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 2.0)).collect();
            let rn2_a = simd::weighted_moments(w, &row, &mut sv_a, &mut sv2_a);
            let rn2_b = simd::weighted_moments_seq_ref(w, &row, &mut sv_b, &mut sv2_b);
            assert_eq!(rn2_a.to_bits(), rn2_b.to_bits(), "rn2 diverged at d={d}");
        }
        for c in 0..d {
            assert_eq!(sv_a[c].to_bits(), sv_b[c].to_bits(), "sum_vec[{c}] diverged");
            assert_eq!(sv2_a[c].to_bits(), sv2_b[c].to_bits(), "sum_vec2[{c}] diverged");
        }
    });
}

#[test]
fn prop_simd_max_fold_and_axpy_match_sequential_reference() {
    // max is associative/commutative on finite floats, so the lane fold
    // must be bitwise-equal to the sequential fold; axpy is elementwise,
    // so every output element must match exactly.
    use vattn::tensor::simd;
    Prop::new("simd-max-axpy-bitwise").cases(100).run(|rng| {
        let d = rng.range(0, 130);
        let xs: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 10.0)).collect();
        let m_fast = simd::max_fold(&xs);
        let m_ref = simd::max_fold_seq_ref(&xs);
        assert_eq!(m_fast.to_bits(), m_ref.to_bits(), "max fold diverged at d={d}");
        let alpha = rng.normal32(0.0, 2.0);
        let x: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0)).collect();
        let y0: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0)).collect();
        let mut y_a = y0.clone();
        let mut y_b = y0;
        simd::axpy(alpha, &x, &mut y_a);
        simd::axpy_seq_ref(alpha, &x, &mut y_b);
        for c in 0..d {
            assert_eq!(y_a[c].to_bits(), y_b[c].to_bits(), "axpy[{c}] diverged at d={d}");
        }
    });
}

#[test]
fn prop_int4_roundtrip_respects_the_advertised_half_scale_bound() {
    // The bit-packed tier's foundational contract, with NO tolerance:
    // for every element of every row — random, constant, zero, and
    // max-magnitude alike — |x − dequantize(quantize(x))| ≤ scale/2
    // with the row's advertised power-of-two scale.
    use vattn::tensor::quant::QuantizedMat4;
    Prop::new("int4-roundtrip-bound").cases(60).run(|rng| {
        let d = [7usize, 8, 15, 16, 31, 32, 64][rng.below(7)];
        let mut m = QuantizedMat4::new(d);
        let mut rows: Vec<Vec<f32>> = Vec::new();
        let magnitude = [0.01f32, 1.0, 100.0, 1e30][rng.below(4)];
        for _ in 0..6 {
            rows.push((0..d).map(|_| rng.normal32(0.0, magnitude)).collect());
        }
        rows.push(vec![0.0; d]); // zero row
        let c = rng.normal32(0.0, magnitude);
        rows.push(vec![c; d]); // constant row
        let mut extreme = vec![f32::MAX; d]; // max-magnitude row
        extreme[d / 2] = -f32::MAX;
        rows.push(extreme);
        for row in &rows {
            m.push_row(row);
        }
        for (r, row) in rows.iter().enumerate() {
            let bound = m.max_abs_err(r);
            assert_eq!(bound, 0.5 * m.scale(r));
            let back = m.dequantize_row(r);
            for (c, (&x, &x_hat)) in row.iter().zip(back.iter()).enumerate() {
                assert!(x_hat.is_finite(), "row {r} col {c} dequantized to {x_hat}");
                assert!(
                    (x - x_hat).abs() <= bound,
                    "row {r} col {c}: |{x} − {x_hat}| > scale/2 = {bound}"
                );
            }
        }
    });
}

#[test]
fn prop_int4_quantization_is_deterministic() {
    // Same row ⇒ same packed bytes and the scale's exact bit pattern.
    use vattn::tensor::quant::quantize_row4_into;
    Prop::new("int4-deterministic").cases(80).run(|rng| {
        let d = rng.range(1, 96);
        let row: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 5.0)).collect();
        let mut a = Vec::new();
        let mut b = Vec::new();
        let sa = quantize_row4_into(&row, &mut a);
        let sb = quantize_row4_into(&row.clone(), &mut b);
        assert_eq!(a, b, "packed codes diverged for identical input");
        assert_eq!(sa.to_bits(), sb.to_bits(), "scales diverged for identical input");
    });
}

#[test]
fn prop_top_indices_are_actually_top() {
    Prop::new("top-indices-correct").cases(80).run(|rng| {
        let n = rng.range(8, 500);
        let scores: Vec<f32> = (0..n).map(|_| rng.normal32(0.0, 1.0)).collect();
        let count = rng.range(1, n + 1);
        let top = top_indices_excluding(&scores, count, &[]);
        assert_eq!(top.len(), count.min(n));
        // min of selected >= max of unselected
        let sel_min = top.iter().map(|&i| scores[i]).fold(f32::INFINITY, f32::min);
        let set: std::collections::HashSet<_> = top.iter().collect();
        let unsel_max = (0..n)
            .filter(|i| !set.contains(i))
            .map(|i| scores[i])
            .fold(f32::NEG_INFINITY, f32::max);
        assert!(sel_min >= unsel_max - 1e-6, "sel_min {sel_min} < unsel_max {unsel_max}");
    });
}

// ---------------------------------------------------------------------
// Json::parse under adversarial input. The parser fronts the network
// server (`server::net`), so its failure mode on hostile bytes is a
// serving concern, not a formatting one: it must error — never panic,
// never mis-parse — and the depth cap must sit exactly where it claims.

/// Structural equality (the enum deliberately doesn't derive PartialEq:
/// production code should never compare trees; tests spell out that NaN
/// payloads and key order are part of "equal").
fn json_eq(a: &Json, b: &Json) -> bool {
    match (a, b) {
        (Json::Null, Json::Null) => true,
        (Json::Bool(x), Json::Bool(y)) => x == y,
        (Json::Num(x), Json::Num(y)) => x == y,
        (Json::Str(x), Json::Str(y)) => x == y,
        (Json::Arr(x), Json::Arr(y)) => {
            x.len() == y.len() && x.iter().zip(y).all(|(a, b)| json_eq(a, b))
        }
        (Json::Obj(x), Json::Obj(y)) => {
            x.len() == y.len()
                && x.iter().zip(y).all(|((ka, va), (kb, vb))| ka == kb && json_eq(va, vb))
        }
        _ => false,
    }
}

/// Random document over every writer-reachable shape: nasty strings
/// (quotes, backslashes, control bytes, multi-byte UTF-8), negative /
/// tiny / huge finite numbers, nested containers, empty containers.
fn random_json(rng: &mut Rng, depth: usize) -> Json {
    let shapes = if depth == 0 { 4 } else { 6 };
    match rng.below(shapes) {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Num(match rng.below(4) {
            0 => rng.range(0, 2000) as f64 - 1000.0,
            1 => rng.normal(),
            2 => rng.normal() * 1e13,
            _ => rng.normal() * 1e-13,
        }),
        3 => Json::Str(random_string(rng)),
        4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => {
            let n = rng.below(4);
            let mut o = Json::obj();
            for i in 0..n {
                let key = format!("{}{i}", random_string(rng));
                o = o.field(&key, random_json(rng, depth - 1));
            }
            o
        }
    }
}

fn random_string(rng: &mut Rng) -> String {
    const ALPHABET: &[char] = &[
        'a', 'Z', '0', ' ', '"', '\\', '\n', '\t', '\r', '\u{1}', '\u{1f}', '/', 'é', 'λ', '∞',
        '語', '\u{10348}',
    ];
    (0..rng.below(12)).map(|_| ALPHABET[rng.below(ALPHABET.len())]).collect()
}

#[test]
fn prop_json_parse_write_roundtrip_is_identity() {
    Prop::new("json-roundtrip").cases(300).run(|rng| {
        let doc = random_json(rng, 4);
        let text = doc.to_string();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("writer output must reparse: {e}\n{text}"));
        assert!(json_eq(&doc, &back), "round trip changed the tree:\n{text}");
    });
}

#[test]
fn prop_json_truncation_always_errors() {
    // Every proper prefix of a container document is incomplete (the
    // top-level bracket only closes at the last byte), so parse must
    // reject all of them — and must do so without panicking.
    Prop::new("json-truncation").cases(120).run(|rng| {
        let doc = match rng.below(2) {
            0 => Json::arr([random_json(rng, 3)]),
            _ => Json::obj().field("k", random_json(rng, 3)),
        };
        let text = doc.to_string();
        for cut in 1..text.len() {
            if !text.is_char_boundary(cut) {
                continue;
            }
            let prefix = &text[..cut];
            assert!(
                Json::parse(prefix).is_err(),
                "truncated doc parsed at byte {cut}:\n{prefix}"
            );
        }
    });
}

#[test]
fn json_depth_cap_holds_exactly_at_the_cap() {
    // The cap counts every value() frame: a scalar under k arrays sits
    // at depth k + 1. 63 arrays + scalar = 64 frames — the documented
    // cap — must parse; one more level must not.
    let at_cap = "[".repeat(63) + "0" + &"]".repeat(63);
    assert!(Json::parse(&at_cap).is_ok(), "depth 64 is within the cap");
    let empty_at_cap = "[".repeat(64) + &"]".repeat(64);
    assert!(Json::parse(&empty_at_cap).is_ok(), "64 nested arrays with no leaf are depth 64");
    let over = "[".repeat(64) + "0" + &"]".repeat(64);
    let err = Json::parse(&over).unwrap_err();
    assert!(err.contains("deeper than 64"), "{err}");
    let way_over = "[".repeat(65) + &"]".repeat(65);
    assert!(Json::parse(&way_over).is_err());
    // Depth is a high-water mark, not a running total: many siblings at
    // a legal depth must not trip the cap.
    let wide = format!("[{}]", vec!["[[0]]"; 100].join(","));
    assert!(Json::parse(&wide).is_ok(), "siblings must not accumulate depth");
}

#[test]
fn json_rejects_nan_literals_and_maps_overflow_to_null_on_write() {
    // JSON has no NaN/Infinity. The literal spellings must all be
    // rejected; an overflowing exponent parses as +inf (f64 semantics)
    // but the writer maps every non-finite back to null, so non-finite
    // values can never round-trip into a results file.
    for bad in ["NaN", "nan", "Infinity", "-Infinity", "inf", "-inf", "+1", "-", "1e", "0x10"] {
        assert!(Json::parse(bad).is_err(), "'{bad}' must not parse");
    }
    let overflow = Json::parse("1e999").expect("overflowing exponent is still a number token");
    assert!(matches!(overflow, Json::Num(x) if x.is_infinite()));
    assert_eq!(overflow.to_string(), "null");
    assert_eq!(Json::Num(f64::NAN).to_string(), "null");
}

#[test]
fn json_duplicate_keys_keep_first_and_survive_reserialization() {
    // The parser preserves duplicates in the tree; get() resolves to
    // the first binding (stable under reserialization, so a consumer
    // re-reading the written form sees the same value).
    let doc = Json::parse("{\"a\": 1, \"b\": 2, \"a\": 3}").unwrap();
    assert_eq!(doc.get("a").unwrap().as_f64(), Some(1.0));
    let rewritten = Json::parse(&doc.to_string()).unwrap();
    assert_eq!(rewritten.get("a").unwrap().as_f64(), Some(1.0));
    assert_eq!(rewritten.get("b").unwrap().as_f64(), Some(2.0));
}
