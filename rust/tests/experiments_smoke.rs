//! Smoke tests for the experiment harness: every registered experiment
//! runs at a reduced scale, produces non-empty output, and writes its
//! results files. (Full-scale runs happen via `vattn exp all`; their
//! outputs are recorded in EXPERIMENTS.md.)

use vattn::experiments;
use vattn::util::cli::Args;

fn quick_args() -> Args {
    Args::parse(
        [
            "--n", "1024", "--d", "32", "--trials", "2", "--steps", "60", "--prompt", "24",
            "--resamples", "60", "--quick",
        ]
        .iter()
        .map(|s| s.to_string()),
    )
}

#[test]
fn every_experiment_runs_at_small_scale() {
    let args = quick_args();
    for (id, _, _) in experiments::registry() {
        // fig5 benches wall-clock; still fine at small n.
        let out = experiments::run(id, &args).unwrap_or_else(|e| panic!("{id}: {e}"));
        assert!(!out.is_empty(), "{id}: empty output");
        assert!(out.contains("##"), "{id}: no table rendered");
        let path = vattn::experiments::common::results_dir().join(format!(
            "{}.json",
            match id {
                "fig1" => "fig1_pareto",
                "fig1-corr" => "fig1_correlation",
                "fig5" => "fig5_speedup",
                "fig11" => "fig11_clt_hoeffding",
                "fig16" => "fig16_ablation",
                "fig18" => "fig18_qq",
                "fig19" => "fig19_sensitivity",
                "table2" => "table2_longgen",
                "appd4" => "appd4_bias",
                other => other,
            }
        ));
        assert!(path.exists(), "{id}: results JSON missing at {path:?}");
    }
}

#[test]
fn registry_listing_is_stable() {
    let ids: Vec<&str> = experiments::registry().iter().map(|(n, _, _)| *n).collect();
    for required in [
        "fig2", "fig1", "fig1-corr", "fig5", "table1", "table2", "table9", "table10",
        "table11", "fig11", "fig16", "fig18", "fig19", "table12", "appd4",
    ] {
        assert!(ids.contains(&required), "missing experiment {required}");
    }
}
