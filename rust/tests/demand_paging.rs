//! Demand-paged KV serving: prefix sharing, incremental allocation, and
//! preemptive scheduling, held to the engine's determinism bar — token
//! streams must be byte-identical whether or not the pool is contended,
//! whether or not prompts fork off the prefix cache, and at any worker
//! count; and every drained session must return every block.

use std::collections::BTreeMap;

use vattn::model::{Model, ModelConfig};
use vattn::server::{
    AttentionMode, Engine, EngineConfig, Event, GenOptions, Request, Session, SessionStats,
    SubmitRequest,
};

/// `n` prompts sharing a common prefix, each with a distinct suffix.
fn shared_prefix_prompts(n: usize, prefix_len: usize, suffix_len: usize) -> Vec<Vec<u32>> {
    let prefix: Vec<u32> = (0..prefix_len as u32).map(|t| (t * 31 + 7) % 250).collect();
    (0..n)
        .map(|i| {
            let mut p = prefix.clone();
            p.extend((0..suffix_len as u32).map(|t| (t * 13 + i as u32 * 17 + 3) % 250));
            p
        })
        .collect()
}

/// Submit every prompt, tick to idle, and return (per-request token
/// streams, paging stats, blocks still resident after a prefix flush).
fn run_session(
    cfg: EngineConfig,
    prompts: &[Vec<u32>],
    gen: usize,
) -> (Vec<Vec<u32>>, SessionStats, usize) {
    let mut s = Session::new(Model::new(ModelConfig::tiny(), 42), cfg);
    let mut streams: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
    for p in prompts {
        let id = s.submit(SubmitRequest::new(p.clone()).options(GenOptions::new(gen)));
        streams.insert(id, Vec::new());
    }
    while !s.is_idle() {
        for ev in s.tick().expect("tick") {
            match ev {
                Event::Token { id, token, step, .. } => {
                    let st = streams.get_mut(&id).expect("token for known request");
                    assert_eq!(st.len(), step, "streams must stay gapless across preemption");
                    st.push(token);
                }
                Event::Finished { id, result, .. } => {
                    assert_eq!(result.tokens, streams[&id], "events must replay the result");
                }
                Event::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
                Event::Admitted { .. } | Event::Preempted { .. } => {}
            }
        }
    }
    let stats = s.stats();
    assert_eq!(
        s.kv_blocks_in_use(),
        s.prefix_blocks_held(),
        "a drained session may hold prefix-cache blocks only"
    );
    s.flush_prefix_cache().expect("flush");
    let residual = s.kv_blocks_in_use();
    (streams.into_values().collect(), stats, residual)
}

#[test]
fn shared_prefix_batch_fits_a_pool_below_worst_case_and_matches_unshared_streams() {
    // 8 requests share a 64-token system prompt (4 full blocks at 16
    // tokens/block) with distinct 16-token suffixes and a 16-token
    // generation budget: worst case is 6 blocks each, 48 in total. A
    // 24-block pool — half the worst-case sum — must serve all of them
    // via demand paging + prefix sharing, with streams byte-identical to
    // an unshared, unbounded run, at worker counts 1 and 4.
    let mcfg = ModelConfig::tiny();
    let prompts = shared_prefix_prompts(8, 64, 16);
    let shared_cfg = |workers: usize| {
        EngineConfig::builder()
            .max_batch(8)
            .workers(workers)
            .block_tokens(16)
            .kv_capacity_bytes(24 * 16 * mcfg.kv_bytes_per_token())
            .prefix_cache(true)
            .build()
    };
    let unshared = EngineConfig::builder().max_batch(8).block_tokens(16).build();

    let (base_streams, base_stats, _) = run_session(unshared, &prompts, 16);
    let (shared1, stats1, residual1) = run_session(shared_cfg(1), &prompts, 16);
    let (shared4, stats4, residual4) = run_session(shared_cfg(4), &prompts, 16);

    assert_eq!(base_streams, shared1, "forked prefixes must not change any token");
    assert_eq!(shared1, shared4, "worker count must not change streams under paging");
    assert_eq!(residual1, 0, "flushed drained session holds zero blocks");
    assert_eq!(residual4, 0);
    assert!(stats1.prefix_hit_blocks > 0, "later admissions must fork off the radix");
    assert_eq!(
        stats1.prefix_hit_blocks, stats4.prefix_hit_blocks,
        "paging decisions are tick-deterministic, independent of workers"
    );
    assert_eq!(stats1.preemptions, stats4.preemptions);
    assert!(
        stats1.peak_blocks_in_use < base_stats.peak_blocks_in_use,
        "sharing must beat the unshared footprint ({} vs {})",
        stats1.peak_blocks_in_use,
        base_stats.peak_blocks_in_use
    );
    assert!(stats1.peak_blocks_in_use <= 24, "capacity is a hard bound");
}

#[test]
fn forced_preemption_leaves_engine_serve_output_unchanged() {
    // Three requests are all admitted on prompt blocks (2 each, pool of
    // 8), then grow toward 5 blocks each — 15 > 8 forces preemption
    // mid-decode. Output must match the unbounded run exactly, at worker
    // counts 1 and 4.
    let mcfg = ModelConfig::tiny();
    let reqs = || -> Vec<Request> {
        (0..3u64)
            .map(|i| {
                let prompt: Vec<u32> = (0..8u32).map(|t| (t * 13 + i as u32) % 250).collect();
                Request::new(i, prompt, 12)
            })
            .collect()
    };
    let run = |cap_blocks: Option<usize>, workers: usize| {
        let mut b = EngineConfig::builder().max_batch(3).workers(workers).block_tokens(4);
        if let Some(cap) = cap_blocks {
            b = b.kv_capacity_bytes(cap * 4 * mcfg.kv_bytes_per_token());
        }
        let eng = Engine::new(Model::new(mcfg.clone(), 42), b.build());
        eng.serve(reqs(), &AttentionMode::Dense).expect("serve")
    };
    let free = run(None, 1);
    for workers in [1usize, 4] {
        let contended = run(Some(8), workers);
        assert_eq!(free.len(), contended.len());
        for (a, b) in free.iter().zip(contended.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.tokens, b.tokens,
                "preemption (workers={workers}) must not change request {}",
                a.id
            );
        }
    }
}

#[test]
fn preemption_actually_fires_and_is_counted_in_session_stats() {
    // Session-level twin of the test above, to pin that the contended
    // configuration really preempts (rather than merely stalling
    // admission) and that the counter reports it.
    let mcfg = ModelConfig::tiny();
    let cfg = EngineConfig::builder()
        .max_batch(3)
        .block_tokens(4)
        .kv_capacity_bytes(8 * 4 * mcfg.kv_bytes_per_token())
        .build();
    let prompts: Vec<Vec<u32>> = (0..3)
        .map(|i| (0..8u32).map(|t| (t * 13 + i) % 250).collect())
        .collect();
    let (_, stats, residual) = run_session(cfg, &prompts, 12);
    assert!(stats.preemptions > 0, "8 blocks < 3 × 5 worst case must preempt");
    assert_eq!(residual, 0);
}

#[test]
fn prefix_eviction_reclaims_blocks_before_resorting_to_preemption() {
    // Distinct prompts fill the radix past what the pool can keep; LRU
    // leaf eviction must fund both later admissions and decode growth,
    // so everything completes with *zero* preemptions.
    let mcfg = ModelConfig::tiny();
    let cfg = EngineConfig::builder()
        .max_batch(1)
        .block_tokens(4)
        .kv_capacity_bytes(8 * 4 * mcfg.kv_bytes_per_token())
        .prefix_cache(true)
        .build();
    let prompts: Vec<Vec<u32>> = (0..4)
        .map(|i| (0..16u32).map(|t| (t * 7 + 100 * i) % 250).collect())
        .collect();
    let (streams, stats, residual) = run_session(cfg, &prompts, 4);
    assert_eq!(streams.len(), 4);
    assert!(streams.iter().all(|s| s.len() == 4));
    assert_eq!(
        stats.preemptions, 0,
        "idle prefix blocks must be reclaimed before anyone is preempted"
    );
    assert_eq!(stats.prefix_hit_blocks, 0, "all prompts are distinct");
    assert!(stats.prefix_blocks_held <= 8, "cache can never exceed the pool");
    assert_eq!(residual, 0);
}

#[test]
fn identical_prompt_replay_hits_the_radix_and_skips_prefill_blocks() {
    // The temporal-reuse story: the same prompt served twice in a row
    // forks its second run off the cache (hit rate > 0) and produces the
    // same greedy stream.
    // max_batch 1 serializes the two runs so the replay sees the radix.
    let cfg = EngineConfig::builder().max_batch(1).block_tokens(4).prefix_cache(true).build();
    let p: Vec<u32> = (0..24u32).map(|t| (t * 11 + 5) % 250).collect();
    let prompts = vec![p.clone(), p];
    let (streams, stats, residual) = run_session(cfg, &prompts, 6);
    assert_eq!(streams[0], streams[1], "replayed prompt must reproduce the stream");
    // 24 tokens = 6 blocks; the second request may share the first 5
    // (the final token's block is never matched).
    assert_eq!(stats.prefix_hit_blocks, 5);
    assert!(stats.prefix_hit_rate() > 0.0);
    assert_eq!(residual, 0);
}
