//! Temporal heavy-hitter reuse, end to end: reuse-enabled serving must
//! stream byte-identical tokens to reuse-disabled serving (the drift
//! certificate only serves *provably* fresh-equal selections), at any
//! worker count, across preemption replays and prefix forks — and the
//! (ε, δ) contract must hold empirically with reuse on.

use std::collections::BTreeMap;

use vattn::attention::{dense_sdpa, sparse_sdpa};
use vattn::model::{Model, ModelConfig};
use vattn::policies::{
    IndexPolicy, PolicyCtx, ReuseConfig, SizeSpec, TemporalReusePolicy, VAttentionConfig,
    VAttentionPolicy,
};
use vattn::server::{
    AttentionOpt, EngineConfig, Event, GenOptions, Session, SessionStats, SubmitRequest,
};
use vattn::tensor::{rel_l2_error, Mat};
use vattn::util::Rng;

fn small_vcfg() -> VAttentionConfig {
    VAttentionConfig {
        sink: SizeSpec::Abs(4),
        window: SizeSpec::Abs(8),
        heavy: SizeSpec::Frac(0.05),
        verify: vattn::budget::Verify::Denominator,
        ..Default::default()
    }
    .with_guarantee(0.2, 0.2)
}

fn prompt(len: usize, salt: u32) -> Vec<u32> {
    (0..len as u32).map(|t| (t * 13 + salt) % 250).collect()
}

fn attention(reuse: bool) -> AttentionOpt {
    if reuse {
        AttentionOpt::VerifiedReuse(small_vcfg(), ReuseConfig::default())
    } else {
        AttentionOpt::Verified(small_vcfg())
    }
}

/// Drive a session to idle collecting per-request token streams (gapless
/// across preemptions, per the Event::Token contract).
fn drain_streams(session: &mut Session<Model>) -> (BTreeMap<u64, Vec<u32>>, SessionStats) {
    let mut streams: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
    while !session.is_idle() {
        for ev in session.tick().expect("tick") {
            match ev {
                Event::Token { id, token, step, .. } => {
                    let st = streams.entry(id).or_default();
                    assert_eq!(st.len(), step, "gapless stream for request {id}");
                    st.push(token);
                }
                Event::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
                _ => {}
            }
        }
    }
    let stats = session.stats();
    (streams, stats)
}

#[test]
fn reuse_streams_byte_identical_to_reuse_off_at_workers_1_and_4() {
    let run = |workers: usize, reuse: bool| {
        let cfg = EngineConfig::builder().max_batch(3).workers(workers).seed(9).build();
        let mut s = Session::new(Model::new(ModelConfig::tiny(), 42), cfg);
        for i in 0..3u32 {
            s.submit(
                SubmitRequest::new(prompt(160 + 16 * i as usize, i))
                    .options(GenOptions::new(24).attention(attention(reuse))),
            );
        }
        drain_streams(&mut s)
    };
    let (off1, off_stats) = run(1, false);
    let (off4, _) = run(4, false);
    let (on1, on_stats1) = run(1, true);
    let (on4, on_stats4) = run(4, true);
    assert_eq!(off1, off4, "reuse-off must be worker-count invariant");
    assert_eq!(on1, on4, "reuse-on must be worker-count invariant");
    assert_eq!(on1, off1, "reuse must not change any token stream");
    assert_eq!(off_stats.reuse.selects, 0, "reuse-off reports no reuse counters");
    let r = &on_stats1.reuse;
    assert!(r.selects > 0);
    assert_eq!(r.selects, r.hits + r.refreshes(), "{r:?}");
    assert_eq!(r.scorer_calls, r.refreshes(), "{r:?}");
    assert_eq!(on_stats1.reuse, on_stats4.reuse, "reuse decisions are worker-invariant");
}

#[test]
fn reuse_state_resets_on_preemption_and_replays_identically() {
    // Two long-generation reuse-enabled requests in a pool that cannot
    // hold both: the preempted request's reuse anchor is reset with its
    // policies, so the replay re-certifies from cold and re-streams the
    // exact tokens of an uncontended run.
    let mcfg = ModelConfig::tiny();
    let contended = EngineConfig::builder()
        .max_batch(2)
        .block_tokens(4)
        .kv_capacity_bytes(7 * 4 * mcfg.kv_bytes_per_token())
        .build();
    let free = EngineConfig::builder().max_batch(2).block_tokens(4).build();
    let run = |cfg: EngineConfig| {
        let mut s = Session::new(Model::new(ModelConfig::tiny(), 42), cfg);
        for i in 0..2u32 {
            s.submit(
                SubmitRequest::new(prompt(8, 1 + i))
                    .options(GenOptions::new(12).attention(attention(true))),
            );
        }
        let mut preemptions = 0;
        let mut streams: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
        while !s.is_idle() {
            for ev in s.tick().expect("tick") {
                match ev {
                    Event::Token { id, token, step, .. } => {
                        let st = streams.entry(id).or_default();
                        assert_eq!(st.len(), step, "stream stays gapless across preemption");
                        st.push(token);
                    }
                    Event::Preempted { .. } => preemptions += 1,
                    Event::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
                    _ => {}
                }
            }
        }
        assert_eq!(s.kv_blocks_in_use(), 0);
        (streams, preemptions, s.stats().reuse)
    };
    let (free_streams, p0, _) = run(free);
    assert_eq!(p0, 0);
    let (contended_streams, p1, reuse) = run(contended);
    assert!(p1 > 0, "7 blocks < 2 × 5 worst case must force preemption");
    assert_eq!(
        free_streams, contended_streams,
        "preempted reuse replay must be byte-identical to the uncontended run"
    );
    // The replay restarted from a cold anchor at least once per
    // preempted request's (layer, head) grid.
    let grid = (ModelConfig::tiny().n_layers * ModelConfig::tiny().n_heads) as u64;
    assert!(
        reuse.refresh_cold >= 2 * grid,
        "expected cold refreshes from admission AND replay: {reuse:?}"
    );
}

#[test]
fn reuse_streams_unchanged_by_prefix_sharing() {
    // Prefix-forked requests share KV blocks but not reuse state; the
    // certificate runs per request and streams must match unshared runs.
    let shared_prompt: Vec<u32> = (0..64u32).map(|t| (t * 37 + 11) % 250).collect();
    let run = |prefix_cache: bool| {
        let cfg = EngineConfig::builder()
            .max_batch(4)
            .block_tokens(4)
            .prefix_cache(prefix_cache)
            .build();
        let mut s = Session::new(Model::new(ModelConfig::tiny(), 42), cfg);
        for i in 0..4u32 {
            let mut p = shared_prompt.clone();
            p.extend((0..8u32).map(|t| (t * 13 + i * 29 + 1) % 250));
            s.submit(SubmitRequest::new(p).options(GenOptions::new(12).attention(attention(true))));
        }
        let (streams, stats) = drain_streams(&mut s);
        if prefix_cache {
            assert!(stats.prefix_hit_blocks > 0, "shared prompts must hit the radix");
        }
        s.flush_prefix_cache().expect("flush");
        assert_eq!(s.kv_blocks_in_use(), 0);
        streams
    };
    let unshared = run(false);
    let shared = run(true);
    assert_eq!(unshared, shared, "prefix forking must not perturb reuse certification");
}

#[test]
fn planted_stable_stream_halves_scorer_invocations() {
    // The acceptance scenario at policy level: planted heavy hitters and
    // a slowly drifting query. Selections must equal a fresh policy's at
    // every step while the underlying scorer runs only on the cold
    // anchor — a ≥ 2x invocation reduction with a wide margin.
    let n = 1024;
    let d = 16;
    let steps = 48;
    let mut rng = Rng::new(5);
    let mut k = Mat::randn(n, d, 0.1, &mut rng);
    let v = Mat::randn(n, d, 1.0, &mut rng);
    for j in 0..8 {
        let row = 100 + j * 4;
        for c in 0..d {
            k.set(row, c, if c == 0 { 10.0 } else { 0.0 });
        }
    }
    let cfg = VAttentionConfig {
        sink: SizeSpec::Abs(4),
        window: SizeSpec::Abs(8),
        heavy: SizeSpec::Abs(8),
        verify: vattn::budget::Verify::Denominator,
        ..Default::default()
    }
    .with_guarantee(0.2, 0.2);
    let mut fresh = VAttentionPolicy::oracle(cfg.clone());
    let mut reused = TemporalReusePolicy::new(
        VAttentionPolicy::oracle(cfg),
        ReuseConfig { max_age: steps + 1, ..Default::default() },
    );
    let mut rng_a = Rng::new(71);
    let mut rng_b = Rng::new(71);
    for step in 0..steps {
        let mut qr = Rng::new(900 + step as u64);
        let q: Vec<f32> = (0..d)
            .map(|c| if c == 0 { 1.0 } else { 0.0 } + 0.01 * qr.normal32(0.0, 1.0))
            .collect();
        let sa =
            fresh.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng_a, step });
        let sb =
            reused.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng_b, step });
        assert_eq!(sa.idx, sb.idx, "selection diverged at step {step}");
        assert_eq!(sa.prob, sb.prob, "probabilities diverged at step {step}");
    }
    let stats = reused.stats();
    assert_eq!(stats.selects, steps as u64);
    assert!(
        stats.scorer_reduction() >= 2.0,
        "stable stream must at least halve scorer invocations: {stats:?}"
    );
    assert_eq!(stats.scorer_calls, 1, "only the cold anchor may scan: {stats:?}");
}

#[test]
fn epsilon_delta_coverage_holds_with_reuse_enabled() {
    // The certificate argument says reuse-enabled selections ARE fresh
    // vAttention selections, so the (ε, δ) contract transfers. Check it
    // empirically anyway: per-trial drifting-query streams, measuring
    // the relative SDPA error of every reused step against dense.
    let n = 1200;
    let d = 16;
    const EPS: f64 = 0.2;
    const DELTA: f64 = 0.15;
    let mut meta = Rng::new(17);
    let mut trials = 0usize;
    let mut violations = 0usize;
    for t in 0..20u64 {
        let mut rng = meta.fork(t);
        let k = Mat::randn(n, d, 1.0, &mut rng);
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let cfg = VAttentionConfig {
            sink: SizeSpec::Abs(16),
            window: SizeSpec::Abs(16),
            heavy: SizeSpec::Frac(0.05),
            base_rate: 0.1,
            verify: vattn::budget::Verify::Sdpa,
            ..Default::default()
        }
        .with_guarantee(EPS, DELTA);
        let mut policy = TemporalReusePolicy::new(
            VAttentionPolicy::oracle(cfg),
            ReuseConfig::default(),
        );
        // A base query with small per-step drift, so some steps are
        // certificate hits and some refresh — both paths are measured.
        let base_q: Vec<f32> =
            (0..d).map(|_| rng.normal32(0.0, 1.0) / (d as f32).sqrt()).collect();
        for step in 0..4 {
            let q: Vec<f32> = base_q
                .iter()
                .map(|x| x + 0.02 * rng.normal32(0.0, 1.0) / (d as f32).sqrt())
                .collect();
            let sel = policy
                .select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step });
            let exact = dense_sdpa(&k, &v, &q).out;
            let approx = sparse_sdpa(&k, &v, &q, &sel);
            trials += 1;
            if rel_l2_error(&approx, &exact) > EPS {
                violations += 1;
            }
        }
    }
    // δ = 0.15 over 80 measured steps ⇒ ~12 expected violations at the
    // contract boundary; allow the same 2x slack the budget-coverage
    // suite uses for CLT asymptotics.
    assert!(trials >= 80);
    let rate = violations as f64 / trials as f64;
    assert!(rate <= 2.0 * DELTA, "violation rate {rate:.3} vs delta {DELTA}");
}
