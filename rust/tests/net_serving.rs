//! End-to-end tests for the network serving front-end: loopback
//! sockets against `NetServer`, checking (1) the network layer adds no
//! nondeterminism — streamed bodies are byte-identical to a direct
//! `Session::tick` run at shards {1,4} × workers {1,4} — (2) client
//! disconnects cancel in-flight requests without leaking KV blocks or
//! cold-tier spill slots, (3) bounded admission sheds with 429 instead
//! of stalling, (4) typed error → HTTP status mapping, and (5) a
//! mid-burst `Router::shutdown` drains every stream to a terminal
//! event, leaves all shards quiescent, and persists the prefix radix so
//! a warm restart on the same spill path replays identical streams.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use vattn::model::{Model, ModelConfig};
use vattn::server::http::read_response;
use vattn::server::{
    EngineConfig, Event, GenOptions, NetServer, Router, RouterConfig, Session, StreamEvent,
    SubmitRequest,
};
use vattn::util::json::Json;

fn prompt(len: usize, salt: u32) -> Vec<u32> {
    (0..len as u32).map(|t| (t * 29 + salt * 7 + 3) % 250).collect()
}

fn start_server(cfg: EngineConfig, shards: usize, depth: usize) -> NetServer {
    let backend = Arc::new(Model::new(ModelConfig::tiny(), 42));
    NetServer::start(backend, "127.0.0.1:0", RouterConfig::new(cfg).shards(shards).queue_depth(depth))
        .expect("bind loopback")
}

/// One full HTTP exchange on a fresh connection.
fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> (u16, Vec<(String, String)>, Vec<u8>) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).expect("send request");
    read_response(&mut s).expect("read response")
}

fn generate_body(prompt: &[u32], gen_len: usize, seed: u64) -> String {
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!("{{\"prompt\":[{}],\"gen_len\":{gen_len},\"seed\":{seed}}}", toks.join(","))
}

fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers.iter().find(|(k, _)| k.eq_ignore_ascii_case(name)).map(|(_, v)| v.as_str())
}

// ─── satellite 1: network determinism ───────────────────────────────

/// What the server must stream for one request, reconstructed from a
/// direct `Session::tick` run: hello line, token lines, done line.
fn direct_bodies(prompts: &[Vec<u32>], gen_len: usize) -> Vec<Vec<u8>> {
    let mut session =
        Session::new(Model::new(ModelConfig::tiny(), 42), EngineConfig::default());
    for (i, p) in prompts.iter().enumerate() {
        // Same seed tags the router pins: sequential global ids — but
        // passed explicitly so this is order-independent by contract.
        let opts = GenOptions::new(gen_len).seed(1000 + i as u64);
        session.submit(SubmitRequest::new(p.clone()).options(opts));
    }
    let mut bodies: Vec<String> =
        (0..prompts.len()).map(|i| format!("{{\"id\":{i}}}\n")).collect();
    let mut done: Vec<usize> = vec![0; prompts.len()];
    while !session.is_idle() {
        for ev in session.tick().expect("tick") {
            match ev {
                Event::Token { id, token, step, .. } => {
                    bodies[id as usize]
                        .push_str(&format!("{{\"step\":{step},\"token\":{token}}}\n"));
                }
                Event::Finished { id, result, .. } => {
                    done[id as usize] = result.tokens.len();
                    bodies[id as usize]
                        .push_str(&format!("{{\"done\":true,\"n\":{}}}\n", result.tokens.len()));
                }
                Event::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
                _ => {}
            }
        }
    }
    assert!(done.iter().all(|&n| n == gen_len), "every request must finish");
    bodies.into_iter().map(String::into_bytes).collect()
}

#[test]
fn loopback_streams_match_direct_session_at_all_shard_worker_counts() {
    let gen_len = 6;
    let prompts: Vec<Vec<u32>> = (0..6).map(|i| prompt(20 + 3 * i, i as u32)).collect();
    let expected = direct_bodies(&prompts, gen_len);

    for shards in [1usize, 4] {
        for workers in [1usize, 4] {
            let cfg = EngineConfig::builder().workers(workers).build();
            let server = start_server(cfg, shards, 64);
            let addr = server.addr();
            // Sequential submission: global ids are 0..n in order, so
            // the full bodies (hello + tokens + done) must be
            // byte-identical to the direct-session reconstruction.
            for (i, p) in prompts.iter().enumerate() {
                let body = generate_body(p, gen_len, 1000 + i as u64);
                let (status, _, resp) = request(addr, "POST", "/v1/generate", Some(&body));
                assert_eq!(status, 200, "shards={shards} workers={workers} req {i}");
                assert_eq!(
                    resp,
                    expected[i],
                    "stream bytes differ from direct session (shards={shards} workers={workers} req {i}):\nnet:    {}\ndirect: {}",
                    String::from_utf8_lossy(&resp),
                    String::from_utf8_lossy(&expected[i]),
                );
            }
            let stats = server.shutdown();
            assert_eq!(stats.iter().map(|s| s.completed).sum::<u64>(), prompts.len() as u64);
            for s in &stats {
                assert_eq!(s.kv_blocks_in_use, 0, "shard {} leaked blocks", s.shard);
            }
        }
    }
}

// ─── satellite 2: disconnect-cancel without leaks (spill mode) ──────

#[test]
fn dropped_sockets_cancel_requests_without_leaking_blocks_or_spill_slots() {
    let mcfg = ModelConfig::tiny();
    let dir = std::env::temp_dir();
    let spill = dir.join(format!("vattn_net_leak_{}.spill", std::process::id()));
    // 50-block pool, 4-token blocks: two 8+192-token requests each need
    // the whole pool, so growth preempts the LIFO victim into the cold
    // tier while the other keeps streaming.
    let cfg = EngineConfig::builder()
        .max_batch(2)
        .block_tokens(4)
        .kv_capacity_bytes(50 * 4 * mcfg.kv_bytes_per_token())
        .kv_spill(&spill)
        .build();
    let server = start_server(cfg, 1, 8);
    let addr = server.addr();

    // Two clients that read the stream start, then hang up.
    let mut socks = Vec::new();
    for i in 0..2u32 {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let body = generate_body(&prompt(8, i), 192, 500 + i as u64);
        let req = format!(
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        s.write_all(req.as_bytes()).unwrap();
        // Wait for streaming to actually start (first token chunk).
        let mut seen = Vec::new();
        let mut chunk = [0u8; 256];
        while !String::from_utf8_lossy(&seen).contains("\"step\":0") {
            let n = s.read(&mut chunk).expect("stream start");
            assert!(n > 0, "server closed early: {}", String::from_utf8_lossy(&seen));
            seen.extend_from_slice(&chunk[..n]);
        }
        socks.push(s);
    }

    // Wait until contention has swapped one request out to the cold
    // tier, so the disconnect path covers suspended state too.
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let s = &server.shard_stats()[0];
        if s.spill_live_blocks.unwrap_or(0) > 0 {
            break;
        }
        assert!(Instant::now() < deadline, "preemption never spilled: {s:?}");
        std::thread::sleep(Duration::from_millis(5));
    }

    // Hang up both clients mid-stream.
    drop(socks);

    // The shard must notice on its next token writes, cancel both, and
    // return every block — warm pool and cold tier.
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let s = &server.shard_stats()[0];
        if s.disconnected == 2
            && s.outstanding == 0
            && s.kv_blocks_in_use == 0
            && s.spill_live_blocks == Some(0)
        {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "disconnect-cancel leaked state: disconnected={} outstanding={} blocks={} spill={:?}",
            s.disconnected,
            s.outstanding,
            s.kv_blocks_in_use,
            s.spill_live_blocks
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    let stats = server.shutdown();
    assert_eq!(stats[0].completed, 0, "neither request should have finished");

    for suffix in ["shard0", "shard0.prefix"] {
        let _ = std::fs::remove_file(format!("{}.{suffix}", spill.display()));
    }
}

// ─── load-shed: 429 instead of stalling ─────────────────────────────

#[test]
fn overcommitted_queue_sheds_with_retriable_429() {
    let cfg = EngineConfig::builder().max_batch(1).build();
    let server = start_server(cfg, 1, 2);
    let addr = server.addr();

    let mut joins = Vec::new();
    for i in 0..10u32 {
        joins.push(std::thread::spawn(move || {
            let body = generate_body(&prompt(24, i), 16, 700 + i as u64);
            request(addr, "POST", "/v1/generate", Some(&body))
        }));
    }
    let mut ok = 0u64;
    let mut shed = 0u64;
    for j in joins {
        let (status, headers, body) = j.join().expect("client thread");
        match status {
            200 => {
                assert!(
                    String::from_utf8_lossy(&body).contains("\"done\":true"),
                    "accepted stream must finish"
                );
                ok += 1;
            }
            429 => {
                assert_eq!(header(&headers, "retry-after"), Some("1"), "429 must be retriable");
                let parsed = Json::parse(&String::from_utf8_lossy(&body)).expect("error body");
                let err = parsed.get("error").expect("error object");
                assert_eq!(err.get("kind").and_then(Json::as_str), Some("shard_queue_full"));
                assert_eq!(err.get("retriable").and_then(Json::as_bool), Some(true));
                shed += 1;
            }
            other => panic!("unexpected status {other}"),
        }
    }
    assert_eq!(ok + shed, 10);
    assert!(ok >= 1, "the first arrival always fits the queue");
    assert!(shed >= 1, "10 concurrent into a depth-2 queue must shed");
    let stats = server.shutdown();
    assert_eq!(stats[0].received, 10);
    assert_eq!(stats[0].shed, shed);
    assert_eq!(stats[0].completed, ok);
}

// ─── typed error → status mapping, cancel route, stats route ────────

#[test]
fn validation_errors_map_to_http_statuses() {
    let mcfg = ModelConfig::tiny();
    let cfg = EngineConfig::builder()
        .max_seq_len(64)
        .block_tokens(16)
        .kv_capacity_bytes(2 * 16 * mcfg.kv_bytes_per_token())
        .build();
    let server = start_server(cfg, 1, 8);
    let addr = server.addr();

    // prompt 48 + gen 32 = 80 > max_seq_len 64 → 400, not retriable.
    let body = generate_body(&prompt(48, 1), 32, 1);
    let (status, headers, resp) = request(addr, "POST", "/v1/generate", Some(&body));
    assert_eq!(status, 400);
    assert!(header(&headers, "retry-after").is_none());
    let parsed = Json::parse(&String::from_utf8_lossy(&resp)).unwrap();
    assert_eq!(
        parsed.get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
        Some("prompt_too_long")
    );

    // prompt 40 + gen 16 = 56 tokens → 4 blocks > 2-block pool → 429.
    let body = generate_body(&prompt(40, 2), 16, 2);
    let (status, headers, resp) = request(addr, "POST", "/v1/generate", Some(&body));
    assert_eq!(status, 429);
    assert_eq!(header(&headers, "retry-after"), Some("1"));
    let parsed = Json::parse(&String::from_utf8_lossy(&resp)).unwrap();
    assert_eq!(
        parsed.get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
        Some("kv_capacity_exceeded")
    );

    // Malformed JSON → 400 before touching the router.
    let (status, _, _) = request(addr, "POST", "/v1/generate", Some("{nope"));
    assert_eq!(status, 400);

    // Unknown request id → 404; unknown route → 404.
    let (status, _, _) = request(addr, "DELETE", "/v1/requests/9999", None);
    assert_eq!(status, 404);
    let (status, _, _) = request(addr, "GET", "/v1/nope", None);
    assert_eq!(status, 404);

    // Liveness probe.
    let (status, _, body) = request(addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    assert_eq!(body, b"{\"ok\":true}");

    server.shutdown();
}

#[test]
fn oversized_request_bodies_get_413_not_a_dropped_socket() {
    use vattn::server::http::MAX_BODY_BYTES;
    let server = start_server(EngineConfig::default(), 1, 8);
    let addr = server.addr();

    // A head whose Content-Length is one past the cap: the server must
    // answer with a proper 413 — previously it just killed the socket,
    // which a client cannot distinguish from a crash.
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let head = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
        MAX_BODY_BYTES + 1
    );
    s.write_all(head.as_bytes()).unwrap();
    let (status, _, body) = read_response(&mut s).expect("reject must still be an HTTP response");
    assert_eq!(status, 413);
    let parsed = Json::parse(&String::from_utf8_lossy(&body)).expect("error body");
    let err = parsed.get("error").expect("error object");
    assert_eq!(err.get("kind").and_then(Json::as_str), Some("payload_too_large"));
    assert_eq!(err.get("retriable").and_then(Json::as_bool), Some(false));
    // The connection closes after the reject (the unread body bytes
    // make keep-alive unsafe): the next read is EOF.
    let mut tail = [0u8; 16];
    assert_eq!(s.read(&mut tail).unwrap_or(0), 0, "server must close after a 413");

    // The listener stays healthy for fresh connections afterwards.
    let (status, _, _) = request(addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    server.shutdown();
}

#[test]
fn cancel_route_terminates_stream_and_stats_report_it() {
    let server = start_server(EngineConfig::default(), 2, 8);
    let addr = server.addr();

    // Long-running request on connection A; read until streaming.
    let mut a = TcpStream::connect(addr).expect("connect");
    a.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let body = generate_body(&prompt(20, 1), 4000, 9);
    let req = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    a.write_all(req.as_bytes()).unwrap();
    let mut seen = Vec::new();
    let mut chunk = [0u8; 256];
    while !String::from_utf8_lossy(&seen).contains("\"step\":0") {
        let n = a.read(&mut chunk).expect("stream start");
        assert!(n > 0, "server closed early");
        seen.extend_from_slice(&chunk[..n]);
    }

    // Cancel it from connection B (first request ⇒ global id 0).
    let (status, _, resp) = request(addr, "DELETE", "/v1/requests/0", None);
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));

    // Connection A's stream must terminate with a cancelled marker.
    loop {
        let n = a.read(&mut chunk).expect("read tail");
        if n == 0 {
            break;
        }
        seen.extend_from_slice(&chunk[..n]);
    }
    assert!(
        String::from_utf8_lossy(&seen).contains("\"cancelled\":true"),
        "stream must end with the cancel marker: {}",
        String::from_utf8_lossy(&seen)
    );

    // Stats route reports the cancel and an idle router.
    let (status, _, body) = request(addr, "GET", "/v1/stats", None);
    assert_eq!(status, 200);
    let parsed = Json::parse(&String::from_utf8_lossy(&body)).expect("stats json");
    let agg = parsed.get("aggregate").expect("aggregate");
    assert_eq!(agg.get("cancelled").and_then(Json::as_usize), Some(1));
    assert_eq!(agg.get("received").and_then(Json::as_usize), Some(1));
    let shards = parsed.get("shards").and_then(Json::as_arr).expect("shards");
    assert_eq!(shards.len(), 2);
    let blocks: usize = shards
        .iter()
        .map(|s| s.get("kv_blocks_in_use").and_then(Json::as_usize).unwrap())
        .sum();
    assert_eq!(blocks, 0, "cancel must return the KV lease");
    server.shutdown();
}

// ─── drain under load: shutdown mid-burst, then warm restart ────────

/// Drain one request's stream to its terminal event: the token vector
/// on completion, the mapped HTTP status on rejection. Anything else
/// (a stall, a cancel we never asked for, a backend failure) panics.
fn drain_stream(rx: &std::sync::mpsc::Receiver<StreamEvent>) -> Result<Vec<u32>, u16> {
    let mut toks = Vec::new();
    loop {
        match rx.recv_timeout(Duration::from_secs(60)).expect("stream event") {
            StreamEvent::Accepted { .. } => {}
            StreamEvent::Token { step, token, .. } => {
                assert_eq!(toks.len(), step, "streams must stay gapless");
                toks.push(token);
            }
            StreamEvent::Finished { result, .. } => {
                assert_eq!(result.tokens, toks, "terminal record must replay the stream");
                return Ok(toks);
            }
            StreamEvent::Rejected { error, .. } => return Err(error.kind.http_status()),
            ev => panic!("unexpected stream event under drain: {ev:?}"),
        }
    }
}

#[test]
fn shutdown_under_load_drains_clean_and_prefix_files_warm_start_a_restart() {
    let mcfg = ModelConfig::tiny();
    let shards = 2usize;
    let spill = std::env::temp_dir()
        .join(format!("vattn_net_drain_{}.spill", std::process::id()));
    let shard_files: Vec<String> = (0..shards)
        .flat_map(|i| {
            [
                format!("{}.shard{i}", spill.display()),
                format!("{}.shard{i}.prefix", spill.display()),
            ]
        })
        .collect();
    for f in &shard_files {
        let _ = std::fs::remove_file(f);
    }

    // Over-committed pool (12 blocks for a burst that wants far more),
    // cold tier attached, prefix cache on, small per-shard queues: the
    // burst below exercises queueing, preemption-to-spill, and shedding
    // all at once — the states a drain must unwind.
    let cfg = EngineConfig::builder()
        .max_batch(2)
        .block_tokens(4)
        .prefix_cache(true)
        .kv_capacity_bytes(12 * 4 * mcfg.kv_bytes_per_token())
        .kv_spill(&spill)
        .build();
    let shared = prompt(8, 99); // two full blocks → shareable prefix
    let tail_prompt = |i: u32| {
        let mut p = shared.clone();
        p.extend(prompt(4 + (i % 3) as usize, i));
        p
    };
    let gen_len = 8usize;

    let backend = Arc::new(Model::new(ModelConfig::tiny(), 42));
    let router =
        Router::new(backend.clone(), RouterConfig::new(cfg.clone()).shards(shards).queue_depth(3));

    // Warm phase: 8 sequential requests populate the prefix radix and
    // pin the reference streams for the restart comparison.
    let mut warm_streams = Vec::new();
    for i in 0..8u32 {
        let (_, rx) = router.submit(tail_prompt(i), GenOptions::new(gen_len).seed(1000 + i as u64));
        let toks = drain_stream(&rx).expect("sequential warm request must complete");
        assert_eq!(toks.len(), gen_len);
        warm_streams.push(toks);
    }

    // Burst phase: 16 concurrent submits, then shutdown while they are
    // still queued/streaming. Every stream must resolve as exactly one
    // of {completed, 429 queue-full, 503 shutting-down} — no stalls, no
    // lost channels.
    let burst: Vec<_> = (0..16u32)
        .map(|i| router.submit(tail_prompt(i), GenOptions::new(gen_len).seed(2000 + i as u64)))
        .collect();
    let stats = router.shutdown();
    let mut completed = 0u64;
    let mut shed429 = 0u64;
    let mut shed503 = 0u64;
    for (_, rx) in &burst {
        match drain_stream(rx) {
            Ok(toks) => {
                assert_eq!(toks.len(), gen_len, "a drained stream must be complete");
                completed += 1;
            }
            Err(429) => shed429 += 1,
            Err(503) => shed503 += 1,
            Err(other) => panic!("drain produced status {other}"),
        }
    }
    assert_eq!(completed + shed429 + shed503, 16, "every burst stream must resolve");
    assert!(shed429 + shed503 > 0, "16-into-depth-3 under shutdown must shed somewhere");

    // Post-drain quiescence, per shard: nothing outstanding, no leaked
    // warm blocks, no orphaned spill slots, prefix radix flushed (its
    // blocks persisted to disk, not held).
    for s in &stats {
        assert_eq!(s.outstanding, 0, "shard {} left requests outstanding", s.shard);
        assert_eq!(s.waiting, 0, "shard {} left requests queued", s.shard);
        assert_eq!(s.active, 0, "shard {} left requests active", s.shard);
        assert_eq!(s.kv_blocks_in_use, 0, "shard {} leaked warm blocks", s.shard);
        assert_eq!(s.prefix_blocks_held, 0, "shard {} still pins prefix blocks", s.shard);
        assert_eq!(s.spill_live_blocks, Some(0), "shard {} orphaned spill slots", s.shard);
    }
    assert_eq!(stats.iter().map(|s| s.completed).sum::<u64>(), 8 + completed);

    // The persisted per-shard prefix radix must exist on disk for at
    // least one shard (the warm phase cached the shared prefix).
    let prefix_files: Vec<&String> =
        shard_files.iter().filter(|f| f.ends_with(".prefix")).collect();
    assert!(
        prefix_files.iter().any(|f| std::path::Path::new(f.as_str()).exists()),
        "no shard persisted its prefix radix: {prefix_files:?}"
    );

    // Warm restart on the same spill path: the radix reloads, so the
    // same requests must hit the prefix cache and stream the same bytes.
    let restarted = Router::new(backend, RouterConfig::new(cfg).shards(shards).queue_depth(3));
    for (i, want) in warm_streams.iter().enumerate() {
        let (_, rx) =
            restarted.submit(tail_prompt(i as u32), GenOptions::new(gen_len).seed(1000 + i as u64));
        let toks = drain_stream(&rx).expect("restarted warm request must complete");
        assert_eq!(&toks, want, "restart changed the stream for request {i}");
    }
    let restat = restarted.shutdown();
    let hit_blocks: u64 = restat.iter().map(|s| s.session.prefix_hit_blocks).sum();
    assert!(hit_blocks > 0, "restarted router never hit the reloaded prefix radix");
    for s in &restat {
        assert_eq!(s.kv_blocks_in_use, 0);
        assert_eq!(s.spill_live_blocks, Some(0));
    }

    for f in &shard_files {
        let _ = std::fs::remove_file(f);
    }
}
