//! PJRT round-trip tests: artifacts load + compile + execute, and the
//! artifact-driven decode step agrees with the rust-native model.
//!
//! Requires `make artifacts` (config=small) to have run; tests skip
//! gracefully when artifacts are missing so `cargo test` works before
//! the python toolchain has been invoked.

use vattn::kvcache::KvCache;
use vattn::model::{Model, ModelConfig};
use vattn::runtime::{bucket_for, PjrtModel, Runtime};
use vattn::tensor::rel_l2_error;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime_or_skip() -> Option<Runtime> {
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping: built without the `pjrt` feature (stub runtime)");
        return None;
    }
    let dir = artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(dir).expect("artifacts must load"))
}

#[test]
fn smoke_artifact_executes() {
    let Some(rt) = runtime_or_skip() else { return };
    assert!(rt.has("smoke"));
    let x = rt.upload(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
    let y = rt.upload(&[1.0, 1.0, 1.0, 1.0], &[2, 2]).unwrap();
    let out = rt.execute_1("smoke", &[&x, &y]).unwrap();
    assert_eq!(out, vec![5.0, 5.0, 9.0, 9.0]);
}

#[test]
fn all_expected_artifacts_present() {
    let Some(rt) = runtime_or_skip() else { return };
    for name in ["qkv", "ffn", "logits", "attn_b128", "attn_b2048"] {
        assert!(rt.has(name), "missing artifact {name}; have {:?}", rt.names());
    }
}

#[test]
fn pjrt_decode_matches_rust_native() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::small();
    let native = Model::new(cfg.clone(), 42);
    let pjrt = PjrtModel::new(rt, cfg.clone(), &native.w).expect("upload weights");

    let mut c_native = KvCache::new(&cfg);
    let mut c_pjrt = KvCache::new(&cfg);
    let prompt = [3u32, 141, 5926, 535, 897, 93];
    let mut last_native = None;
    let mut last_pjrt = None;
    for (pos, &t) in prompt.iter().enumerate() {
        last_native = Some(native.decode_step(t, pos, &mut c_native, None));
        last_pjrt = Some(pjrt.decode_step(t, pos, &mut c_pjrt, None).expect("pjrt step"));
    }
    let a = last_native.unwrap();
    let b = last_pjrt.unwrap();
    assert_eq!(a.logits.len(), cfg.vocab);
    let err = rel_l2_error(&b.logits, &a.logits);
    assert!(err < 5e-3, "pjrt vs native logits rel err {err}");
    // caches must agree too
    let (kn, _) = c_native.head(0, 0);
    let (kp, _) = c_pjrt.head(0, 0);
    assert_eq!(kn.rows, kp.rows);
    let kerr = rel_l2_error(&kp.data, &kn.data);
    assert!(kerr < 1e-3, "cache K rel err {kerr}");
}

#[test]
fn pjrt_sparse_selection_reduces_traffic_and_stays_close() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = ModelConfig::small();
    let native = Model::new(cfg.clone(), 7);
    let pjrt = PjrtModel::new(rt, cfg.clone(), &native.w).expect("upload weights");

    // Build a 200-token cache densely, twice (sparse run + dense control).
    let build = |pjrt: &PjrtModel| {
        let mut c = KvCache::new(&cfg);
        for pos in 0..200 {
            pjrt.decode_step((pos % 97) as u32, pos, &mut c, None).unwrap();
        }
        c
    };
    let mut c_dense = build(&pjrt);
    let dense = pjrt.decode_step(11, 200, &mut c_dense, None).unwrap();

    let mut c_sparse = build(&pjrt);
    let mut select = |_l: usize,
                      _h: usize,
                      k: &vattn::tensor::Mat,
                      _v: &vattn::tensor::Mat,
                      q: &[f32],
                      _qb: Option<vattn::tensor::quant::KvQuantBounds>| {
        // oracle top-64 + sink/window
        let logits = vattn::attention::logits_all(k, q);
        let mut idx = vattn::policies::sink_window_indices(k.rows, 8, 16);
        let top = vattn::policies::top_indices_excluding(&logits, 64, &idx);
        idx.extend(top);
        idx.sort_unstable();
        vattn::attention::Selection::deterministic(idx)
    };
    c_sparse.stats.reset();
    let sparse = pjrt.decode_step(11, 200, &mut c_sparse, Some(&mut select)).unwrap();
    assert!(sparse.mean_density < 0.55, "density {}", sparse.mean_density);
    assert!(c_sparse.stats.bytes_read > 0);
    // top-heavy selection keeps logits close on a random-weight model
    let err = rel_l2_error(&sparse.logits, &dense.logits);
    assert!(err < 0.35, "sparse vs dense logits err {err}");
}

#[test]
fn bucket_function_covers_all_artifact_buckets() {
    for b in vattn::runtime::BUDGET_BUCKETS {
        assert_eq!(bucket_for(b), Some(b));
    }
}
