"""AOT pipeline tests: each block lowers to parseable HLO text with the
expected parameter arity, and lowering is deterministic (stable hashes).
Uses the tiny config to stay fast."""

import hashlib

from compile import aot
from compile import model as M

CFG = M.ModelConfig.tiny()


def test_qkv_lowers_to_hlo_text():
    text = aot.lower_qkv(CFG)
    assert "HloModule" in text
    assert "ENTRY" in text
    # 7 parameters: x, w_ln, wq, wk, wv, cos, sin
    assert text.count("parameter(") >= 7  # entry params (+ fused-computation params)


def test_attn_lowers_with_budget():
    text = aot.lower_attn(CFG, 128)
    assert "HloModule" in text
    # gathered keys shape must appear: [h, 128, dh]
    assert f"f32[{CFG.n_heads},128,{CFG.d_head}]" in text
    assert text.count("parameter(") >= 6


def test_ffn_and_logits_lower():
    assert "HloModule" in aot.lower_ffn(CFG)
    text = aot.lower_logits(CFG)
    assert f"f32[{CFG.vocab},{CFG.d_model}]" in text


def test_smoke_lowering():
    text = aot.lower_smoke()
    assert "HloModule" in text


def test_lowering_is_deterministic():
    a = hashlib.sha256(aot.lower_ffn(CFG).encode()).hexdigest()
    b = hashlib.sha256(aot.lower_ffn(CFG).encode()).hexdigest()
    assert a == b


def test_budget_buckets_sane():
    assert aot.BUDGET_BUCKETS == sorted(aot.BUDGET_BUCKETS)
    assert all(b % 128 == 0 for b in aot.BUDGET_BUCKETS)
