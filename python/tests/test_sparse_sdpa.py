"""L1 correctness: Pallas sparse_sdpa kernel vs the pure-jnp oracle.

This is the core kernel-level correctness signal: the estimator of Eq. 3
(importance-weighted, masked, max-stabilized) must match ref.py to float
tolerance across shapes, budgets, masks and weight patterns.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.ref import dense_sdpa_ref, sparse_sdpa_ref
from compile.kernels.sparse_sdpa import TILE_B, sparse_sdpa


def make_inputs(h, b, dh, seed, p_det=0.5, mask_frac=1.0):
    rng = np.random.default_rng(seed)
    q = rng.normal(0, 1, (h, dh)).astype(np.float32) / np.sqrt(dh)
    kg = rng.normal(0, 1, (h, b, dh)).astype(np.float32)
    vg = rng.normal(0, 1, (h, b, dh)).astype(np.float32)
    # importance weights: some deterministic (log 1/p = 0), some sampled
    probs = np.where(
        rng.random((h, b)) < p_det, 1.0, rng.uniform(0.05, 0.9, (h, b))
    ).astype(np.float32)
    log_invp = -np.log(probs)
    n_valid = max(1, int(b * mask_frac))
    mask = np.zeros((h, b), np.float32)
    mask[:, :n_valid] = 1.0
    return q, kg, vg, log_invp.astype(np.float32), mask


def assert_matches_ref(q, kg, vg, log_invp, mask, atol=2e-5):
    got = np.asarray(sparse_sdpa(q, kg, vg, log_invp, mask))
    want = np.asarray(sparse_sdpa_ref(q, kg, vg, log_invp, mask))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=atol)


class TestBasic:
    def test_single_head_single_tile(self):
        assert_matches_ref(*make_inputs(1, TILE_B, 32, seed=0))

    def test_multi_head(self):
        assert_matches_ref(*make_inputs(4, TILE_B, 64, seed=1))

    def test_multi_tile(self):
        assert_matches_ref(*make_inputs(2, 4 * TILE_B, 32, seed=2))

    def test_large_budget(self):
        assert_matches_ref(*make_inputs(2, 16 * TILE_B, 64, seed=3))

    def test_rejects_unaligned_budget(self):
        q, kg, vg, lp, mk = make_inputs(1, TILE_B, 16, seed=4)
        with pytest.raises(ValueError):
            sparse_sdpa(q, kg[:, :100], vg[:, :100], lp[:, :100], mk[:, :100])


class TestMasking:
    def test_half_masked(self):
        assert_matches_ref(*make_inputs(2, 2 * TILE_B, 32, seed=5, mask_frac=0.5))

    def test_single_valid_slot(self):
        q, kg, vg, lp, mk = make_inputs(1, TILE_B, 16, seed=6)
        mk[:] = 0.0
        mk[:, 0] = 1.0
        # with one valid deterministic slot the output is exactly v[0]
        lp[:] = 0.0
        got = np.asarray(sparse_sdpa(q, kg, vg, lp, mk))
        np.testing.assert_allclose(got, kg[:, 0] * 0 + vg[:, 0], rtol=1e-5, atol=1e-5)

    def test_padding_values_are_ignored(self):
        q, kg, vg, lp, mk = make_inputs(2, 2 * TILE_B, 32, seed=7, mask_frac=0.75)
        out1 = np.asarray(sparse_sdpa(q, kg, vg, lp, mk))
        # poison the padded slots: result must not change
        kg2 = kg.copy()
        vg2 = vg.copy()
        kg2[mk == 0] = 1e6
        vg2[mk == 0] = -1e6
        out2 = np.asarray(sparse_sdpa(q, kg2, vg2, lp, mk))
        np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)


class TestEstimatorSemantics:
    def test_all_deterministic_equals_dense(self):
        """log_invp = 0, full mask -> plain dense attention over the rows."""
        h, b, dh = 2, 2 * TILE_B, 32
        q, kg, vg, _, mask = make_inputs(h, b, dh, seed=8)
        zero = np.zeros((h, b), np.float32)
        got = np.asarray(sparse_sdpa(q, kg, vg, zero, mask))
        want = np.asarray(dense_sdpa_ref(q, kg, vg))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    def test_importance_weights_shift_output(self):
        q, kg, vg, lp, mk = make_inputs(1, TILE_B, 16, seed=9, p_det=0.0)
        out_w = np.asarray(sparse_sdpa(q, kg, vg, lp, mk))
        out_nw = np.asarray(sparse_sdpa(q, kg, vg, np.zeros_like(lp), mk))
        assert not np.allclose(out_w, out_nw)

    def test_uniform_invp_is_noop(self):
        """A constant 1/p multiplies N and D equally -> same output."""
        q, kg, vg, _, mk = make_inputs(2, TILE_B, 32, seed=10)
        const = np.full((2, TILE_B), np.log(4.0), np.float32)
        a = np.asarray(sparse_sdpa(q, kg, vg, const, mk))
        b = np.asarray(sparse_sdpa(q, kg, vg, np.zeros_like(const), mk))
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)

    def test_huge_logits_stable(self):
        q, kg, vg, lp, mk = make_inputs(1, TILE_B, 16, seed=11)
        kg = kg * 60.0  # exp would overflow unstabilized f32
        out = np.asarray(sparse_sdpa(q, kg, vg, lp, mk))
        assert np.all(np.isfinite(out))
        want = np.asarray(sparse_sdpa_ref(q, kg, vg, lp, mk))
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    h=st.integers(1, 4),
    tiles=st.integers(1, 4),
    dh=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**16),
    mask_frac=st.floats(0.1, 1.0),
)
def test_hypothesis_sweep(h, tiles, dh, seed, mask_frac):
    """Property: kernel == oracle over random shape/mask/weight configs."""
    assert_matches_ref(*make_inputs(h, tiles * TILE_B, dh, seed, mask_frac=mask_frac))
