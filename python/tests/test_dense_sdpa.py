"""L1 correctness: Pallas dense_sdpa kernel vs the pure-jnp oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.dense_sdpa import TILE_N, dense_sdpa
from compile.kernels.ref import dense_sdpa_ref


def make_inputs(h, n, dh, seed):
    rng = np.random.default_rng(seed)
    q = rng.normal(0, 1, (h, dh)).astype(np.float32) / np.sqrt(dh)
    k = rng.normal(0, 1, (h, n, dh)).astype(np.float32)
    v = rng.normal(0, 1, (h, n, dh)).astype(np.float32)
    return q, k, v


def test_single_tile():
    q, k, v = make_inputs(2, TILE_N, 32, 0)
    np.testing.assert_allclose(
        np.asarray(dense_sdpa(q, k, v)), np.asarray(dense_sdpa_ref(q, k, v)), rtol=2e-4, atol=2e-5
    )


def test_many_tiles():
    q, k, v = make_inputs(3, 8 * TILE_N, 64, 1)
    np.testing.assert_allclose(
        np.asarray(dense_sdpa(q, k, v)), np.asarray(dense_sdpa_ref(q, k, v)), rtol=2e-4, atol=2e-5
    )


def test_unaligned_context_rejected():
    q, k, v = make_inputs(1, TILE_N, 16, 2)
    with pytest.raises(ValueError):
        dense_sdpa(q, k[:, :100], v[:, :100])


def test_softmax_weights_dominated_by_planted_key():
    """Plant a huge-logit key: output converges to its value."""
    q, k, v = make_inputs(1, 2 * TILE_N, 16, 3)
    k[0, 37] = q[0] * 1e3
    out = np.asarray(dense_sdpa(q, k, v))
    np.testing.assert_allclose(out[0], v[0, 37], rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    h=st.integers(1, 4),
    tiles=st.integers(1, 4),
    dh=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_sweep(h, tiles, dh, seed):
    q, k, v = make_inputs(h, tiles * TILE_N, dh, seed)
    np.testing.assert_allclose(
        np.asarray(dense_sdpa(q, k, v)), np.asarray(dense_sdpa_ref(q, k, v)), rtol=2e-4, atol=2e-5
    )
