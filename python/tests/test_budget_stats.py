"""L1 correctness: Pallas budget_stats moment kernel vs the jnp oracle,
plus semantic checks that the moments reconstruct the Algorithm-2
statistics correctly."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.budget_stats import TILE_B, budget_stats
from compile.kernels.ref import budget_stats_ref


def make_inputs(b0, dh, seed, m_ref=0.5):
    rng = np.random.default_rng(seed)
    q = rng.normal(0, 1, (dh,)).astype(np.float32) / np.sqrt(dh)
    kb = rng.normal(0, 1, (b0, dh)).astype(np.float32)
    vb = rng.normal(0, 1, (b0, dh)).astype(np.float32)
    return q, kb, vb, np.array([m_ref], np.float32)


def run_both(q, kb, vb, m_ref):
    s, sv = budget_stats(q, kb, vb, m_ref)
    s = np.asarray(s)
    sv = np.asarray(sv)
    rs = budget_stats_ref(q, kb, vb, m_ref[0])
    return (s[0], s[1], sv[0], sv[1]), tuple(np.asarray(x) for x in rs)


def test_matches_ref_single_tile():
    got, want = run_both(*make_inputs(TILE_B, 32, 0))
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=2e-4, atol=2e-5)


def test_matches_ref_multi_tile():
    got, want = run_both(*make_inputs(4 * TILE_B, 64, 1))
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=2e-4, atol=1e-4)


def test_variance_reconstruction():
    """sigma^2 from the moments == np.var of the exp weights."""
    q, kb, vb, m_ref = make_inputs(2 * TILE_B, 32, 2)
    (sum_w, sum_w2, _, _), _ = run_both(q, kb, vb, m_ref)
    b0 = kb.shape[0]
    mean = sum_w / b0
    var_hat = (sum_w2 - b0 * mean * mean) / (b0 - 1)
    w = np.exp(kb @ q - m_ref[0])
    np.testing.assert_allclose(var_hat, np.var(w, ddof=1), rtol=1e-3)


def test_m_ref_shift_scales_weights():
    """Shifting m_ref by c multiplies sum_w by exp(-c)."""
    q, kb, vb, _ = make_inputs(TILE_B, 16, 3)
    (s0, _, _, _), _ = run_both(q, kb, vb, np.array([0.0], np.float32))
    (s1, _, _, _), _ = run_both(q, kb, vb, np.array([1.0], np.float32))
    np.testing.assert_allclose(s1 * np.e, s0, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    tiles=st.integers(1, 4),
    dh=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**16),
    m_ref=st.floats(-1.0, 2.0),
)
def test_hypothesis_sweep(tiles, dh, seed, m_ref):
    got, want = run_both(*make_inputs(tiles * TILE_B, dh, seed, m_ref))
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=3e-4, atol=1e-4)
