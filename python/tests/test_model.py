"""L2 correctness: model blocks — shapes, RoPE/RMSNorm semantics, and the
attention block's agreement with a hand-rolled numpy decode step."""

import numpy as np

import jax.numpy as jnp

from compile import model as M
from compile.kernels.ref import sparse_sdpa_ref


CFG = M.ModelConfig.tiny()


def rand_weights(cfg, seed=0):
    rng = np.random.default_rng(seed)
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    w = {
        "w_ln": rng.normal(1.0, 0.02, (d,)).astype(np.float32),
        "wq": rng.normal(0, 0.05, (d, d)).astype(np.float32),
        "wk": rng.normal(0, 0.05, (d, d)).astype(np.float32),
        "wv": rng.normal(0, 0.05, (d, d)).astype(np.float32),
        "wo": rng.normal(0, 0.05, (d, d)).astype(np.float32),
        "w_gate": rng.normal(0, 0.05, (d, f)).astype(np.float32),
        "w_up": rng.normal(0, 0.05, (d, f)).astype(np.float32),
        "w_down": rng.normal(0, 0.05, (f, d)).astype(np.float32),
        "w_emb": rng.normal(0, 0.05, (v, d)).astype(np.float32),
    }
    return w


def rope_phases(pos, dh, base=10000.0):
    half = dh // 2
    inv = 1.0 / base ** (np.arange(half) / half)
    ang = pos * inv
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)


class TestShapes:
    def test_qkv_shapes(self):
        w = rand_weights(CFG)
        x = np.ones((1, CFG.d_model), np.float32)
        cos, sin = rope_phases(3, CFG.d_head)
        q, k, v = M.qkv_block(x, w["w_ln"], w["wq"], w["wk"], w["wv"], cos, sin, CFG)
        assert q.shape == (CFG.n_heads, CFG.d_head)
        assert k.shape == (CFG.n_heads, CFG.d_head)
        assert v.shape == (CFG.n_heads, CFG.d_head)

    def test_ffn_shape(self):
        w = rand_weights(CFG)
        x = np.ones((1, CFG.d_model), np.float32)
        out = M.ffn_block(x, w["w_ln"], w["w_gate"], w["w_up"], w["w_down"])
        assert out.shape == (1, CFG.d_model)

    def test_logits_shape(self):
        w = rand_weights(CFG)
        x = np.ones((1, CFG.d_model), np.float32)
        out = M.logits_block(x, w["w_ln"], w["w_emb"])
        assert out.shape == (1, CFG.vocab)


class TestSemantics:
    def test_rmsnorm_unit_scale(self):
        x = np.array([[3.0, -4.0]], np.float32)
        out = np.asarray(M.rmsnorm(x, np.ones(2, np.float32)))
        # rms of [3,-4] is sqrt(12.5); normalized vector has rms 1
        rms = np.sqrt(np.mean(out**2))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-4)

    def test_rope_preserves_norm(self):
        dh = CFG.d_head
        x = np.random.default_rng(1).normal(0, 1, (CFG.n_heads, dh)).astype(np.float32)
        cos, sin = rope_phases(17, dh)
        y = np.asarray(M.apply_rope(x, cos, sin))
        np.testing.assert_allclose(
            np.linalg.norm(y, axis=-1), np.linalg.norm(x, axis=-1), rtol=1e-5
        )

    def test_rope_position_zero_is_identity(self):
        dh = CFG.d_head
        x = np.random.default_rng(2).normal(0, 1, (2, dh)).astype(np.float32)
        cos, sin = rope_phases(0, dh)
        np.testing.assert_allclose(np.asarray(M.apply_rope(x, cos, sin)), x, rtol=1e-6)

    def test_rope_relative_property(self):
        """<rope(q,m), rope(k,n)> depends only on m-n (per 2-dim plane)."""
        dh = CFG.d_head
        rng = np.random.default_rng(3)
        q = rng.normal(0, 1, (1, dh)).astype(np.float32)
        k = rng.normal(0, 1, (1, dh)).astype(np.float32)
        def ip(m, n):
            cq, sq = rope_phases(m, dh)
            ck, sk = rope_phases(n, dh)
            prod = np.asarray(M.apply_rope(q, cq, sq)) @ np.asarray(M.apply_rope(k, ck, sk)).T
            return float(prod[0, 0])
        np.testing.assert_allclose(ip(5, 3), ip(9, 7), rtol=1e-4)

    def test_attn_block_matches_manual(self):
        w = rand_weights(CFG)
        h, dh, d = CFG.n_heads, CFG.d_head, CFG.d_model
        b = 128
        rng = np.random.default_rng(4)
        q = rng.normal(0, 1, (h, dh)).astype(np.float32)
        kg = rng.normal(0, 1, (h, b, dh)).astype(np.float32)
        vg = rng.normal(0, 1, (h, b, dh)).astype(np.float32)
        lp = np.zeros((h, b), np.float32)
        mask = np.ones((h, b), np.float32)
        got = np.asarray(M.attn_block(q, kg, vg, lp, mask, w["wo"], CFG))
        want = np.asarray(sparse_sdpa_ref(q, kg, vg, lp, mask)).reshape(1, d) @ w["wo"]
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    def test_ffn_swiglu_zero_gate_is_zero(self):
        w = rand_weights(CFG)
        x = np.zeros((1, CFG.d_model), np.float32)
        out = np.asarray(M.ffn_block(x, w["w_ln"], w["w_gate"], w["w_up"], w["w_down"]))
        np.testing.assert_allclose(out, 0.0, atol=1e-6)

    def test_logits_tied_head(self):
        """Logit of token t == <norm(x), emb[t]>."""
        w = rand_weights(CFG)
        x = np.random.default_rng(5).normal(0, 1, (1, CFG.d_model)).astype(np.float32)
        logits = np.asarray(M.logits_block(x, w["w_ln"], w["w_emb"]))
        xn = np.asarray(M.rmsnorm(jnp.asarray(x), w["w_ln"]))
        np.testing.assert_allclose(logits[0, 7], float((xn @ w["w_emb"][7]).item()), rtol=1e-4)
