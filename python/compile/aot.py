"""AOT pipeline: lower the L2 blocks to HLO *text* under artifacts/.

Run once via `make artifacts` (no-op when inputs are unchanged); the rust
runtime (`rust/src/runtime/`) loads these with
`HloModuleProto::from_text_file`, compiles them on the PJRT CPU client,
and executes them on the request path. Python never runs at serve time.

HLO text — NOT `lowered.compiler_ir("hlo").as_hlo_module().serialize()` —
is the interchange format: jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Emitted artifacts (shapes in the manifest artifacts/manifest.txt):

    qkv.hlo.txt          rmsnorm + QKV + RoPE
    attn_b{B}.hlo.txt    gathered sparse SDPA + O-proj, B in BUDGET_BUCKETS
    ffn.hlo.txt          rmsnorm + SwiGLU
    logits.hlo.txt       final norm + LM head
    smoke.hlo.txt        tiny matmul used by runtime self-tests
"""

import argparse
import hashlib
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model as M  # noqa: E402

# Budget buckets: rust rounds every adaptive budget up to one of these so
# each bucket compiles to one static-shape executable.
BUDGET_BUCKETS = [128, 256, 512, 1024, 2048]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_qkv(cfg: M.ModelConfig) -> str:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head

    def fn(x, w_ln, wq, wk, wv, cos, sin):
        return M.qkv_block(x, w_ln, wq, wk, wv, cos, sin, cfg)

    lowered = jax.jit(fn).lower(
        f32(1, d), f32(d), f32(d, d), f32(d, d), f32(d, d), f32(dh // 2), f32(dh // 2)
    )
    return to_hlo_text(lowered)


def lower_attn(cfg: M.ModelConfig, budget: int) -> str:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head

    def fn(q, kg, vg, log_invp, mask, wo):
        return (M.attn_block(q, kg, vg, log_invp, mask, wo, cfg),)

    lowered = jax.jit(fn).lower(
        f32(h, dh), f32(h, budget, dh), f32(h, budget, dh), f32(h, budget), f32(h, budget), f32(d, d)
    )
    return to_hlo_text(lowered)


def lower_ffn(cfg: M.ModelConfig) -> str:
    d, f = cfg.d_model, cfg.d_ff

    def fn(x, w_ln, w_gate, w_up, w_down):
        return (M.ffn_block(x, w_ln, w_gate, w_up, w_down),)

    lowered = jax.jit(fn).lower(f32(1, d), f32(d), f32(d, f), f32(d, f), f32(f, d))
    return to_hlo_text(lowered)


def lower_logits(cfg: M.ModelConfig) -> str:
    d, v = cfg.d_model, cfg.vocab

    def fn(x, w_ln, w_emb):
        return (M.logits_block(x, w_ln, w_emb),)

    lowered = jax.jit(fn).lower(f32(1, d), f32(d), f32(v, d))
    return to_hlo_text(lowered)


def lower_smoke() -> str:
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = f32(2, 2)
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))), "artifacts"))
    ap.add_argument("--config", default="small", choices=["tiny", "small"])
    args = ap.parse_args()

    cfg = M.ModelConfig.tiny() if args.config == "tiny" else M.ModelConfig.small()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    artifacts = {
        "qkv.hlo.txt": lower_qkv(cfg),
        "ffn.hlo.txt": lower_ffn(cfg),
        "logits.hlo.txt": lower_logits(cfg),
        "smoke.hlo.txt": lower_smoke(),
    }
    for b in BUDGET_BUCKETS:
        artifacts[f"attn_b{b}.hlo.txt"] = lower_attn(cfg, b)

    manifest = [
        f"config={args.config}",
        f"d_model={cfg.d_model} n_heads={cfg.n_heads} d_head={cfg.d_head} "
        f"d_ff={cfg.d_ff} vocab={cfg.vocab} n_layers={cfg.n_layers}",
        f"budget_buckets={','.join(str(b) for b in BUDGET_BUCKETS)}",
    ]
    for name, text in sorted(artifacts.items()):
        path = os.path.join(out, name)
        with open(path, "w") as fh:
            fh.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest.append(f"{name} bytes={len(text)} sha256={digest}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(out, "manifest.txt"), "w") as fh:
        fh.write("\n".join(manifest) + "\n")
    print(f"wrote {os.path.join(out, 'manifest.txt')}")


if __name__ == "__main__":
    main()
