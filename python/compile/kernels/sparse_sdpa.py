"""L1 Pallas kernel: importance-weighted sparse SDPA (Eq. 3).

The paper's sparse attention computes, over selected indices with
selection probabilities p_i,

    out = sum_i (1/p_i) exp<k_i, q> v_i  /  sum_i (1/p_i) exp<k_i, q>.

GPU implementations gather selected KV rows from HBM with warp-level
loads; the TPU/Pallas re-expression (DESIGN.md §4 "Hardware adaptation")
stages the gathered rows through VMEM in `TILE_B`-sized blocks and fuses
the importance weights into the max-stabilized softmax as additive
log(1/p) terms, keeping one running (m, l, acc) triple per head —
flash-attention structure with the estimator folded in.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so lowering must stay in plain-HLO land. Real-TPU VMEM and
MXU estimates for this kernel are in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Budget tile staged into VMEM per step. 128 matches the MXU lane width;
# at dh=64 one (K, V) tile pair is 2*128*64*4 = 64 KiB — double-buffered
# comfortably inside the ~16 MiB VMEM budget.
TILE_B = 128


def _sparse_sdpa_kernel(q_ref, kg_ref, vg_ref, logp_ref, mask_ref, o_ref, *, tiles):
    """One grid step handles one head; loops over budget tiles in VMEM."""
    q = q_ref[0, :]  # [dh]

    def tile_step(t, carry):
        m_run, l_run, acc = carry
        kt = kg_ref[0, pl.dslice(t * TILE_B, TILE_B), :]      # [TB, dh]
        vt = vg_ref[0, pl.dslice(t * TILE_B, TILE_B), :]      # [TB, dh]
        lp = logp_ref[0, pl.dslice(t * TILE_B, TILE_B)]       # [TB]
        mk = mask_ref[0, pl.dslice(t * TILE_B, TILE_B)]       # [TB]
        logits = kt @ q + lp                                   # [TB]
        logits = jnp.where(mk > 0, logits, -jnp.inf)
        m_new = jnp.maximum(m_run, jnp.max(logits))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        # Rescale the running accumulator to the new max.
        scale = jnp.where(jnp.isfinite(m_run), jnp.exp(m_run - m_safe), 0.0)
        w = jnp.exp(logits - m_safe)                           # [TB]
        w = jnp.where(mk > 0, w, 0.0)
        l_new = l_run * scale + jnp.sum(w)
        acc_new = acc * scale + w @ vt                         # [dh]
        return m_new, l_new, acc_new

    dh = q.shape[-1]
    init = (-jnp.inf, jnp.float32(0.0), jnp.zeros((dh,), jnp.float32))
    m_fin, l_fin, acc = jax.lax.fori_loop(0, tiles, tile_step, init)
    del m_fin
    o_ref[0, :] = acc / jnp.maximum(l_fin, 1e-30)


def sparse_sdpa(q, kg, vg, log_invp, mask):
    """Pallas sparse SDPA. Shapes as in `ref.sparse_sdpa_ref`.

    Requires the budget dimension B to be a multiple of TILE_B (the AOT
    pipeline buckets budgets to {128, 256, 512, 1024, 2048}); pad with
    mask=0 slots to reach a bucket.
    """
    h, b, dh = kg.shape
    if b % TILE_B != 0:
        raise ValueError(f"budget {b} must be a multiple of {TILE_B}")
    tiles = b // TILE_B
    kernel = functools.partial(_sparse_sdpa_kernel, tiles=tiles)
    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, dh), lambda i: (i, 0)),
            pl.BlockSpec((1, b, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b), lambda i: (i, 0)),
            pl.BlockSpec((1, b), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, dh), jnp.float32),
        interpret=True,
    )(q, kg, vg, log_invp, mask)
