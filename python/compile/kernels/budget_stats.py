"""L1 Pallas kernel: base-sample moment accumulation for Algorithm 2.

Computes, over the base-sample rows (kb, vb) and the query q:

    w_i        = exp(<kb_i, q> - m_ref)
    sum_w      = sum_i w_i
    sum_w2     = sum_i w_i^2
    sum_wv[c]  = sum_i w_i vb_i[c]
    sum_w2v2[c]= sum_i (w_i vb_i[c])^2

which are exactly the raw moments the rust budget module combines into
sigma^2 (denominator), Tr(Sigma) (numerator), D-hat and ||N-hat||_2. One
fused pass over the sample keeps the base-sample traffic HBM->VMEM once.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_B = 128


def _stats_kernel(q_ref, kb_ref, vb_ref, mref_ref, s_ref, sv_ref, *, tiles):
    q = q_ref[...]
    m_ref = mref_ref[0]

    def tile_step(t, carry):
        s_w, s_w2, s_wv, s_w2v2 = carry
        kt = kb_ref[pl.dslice(t * TILE_B, TILE_B), :]
        vt = vb_ref[pl.dslice(t * TILE_B, TILE_B), :]
        w = jnp.exp(kt @ q - m_ref)  # [TB]
        wv = w[:, None] * vt          # [TB, dh]
        return (
            s_w + jnp.sum(w),
            s_w2 + jnp.sum(w * w),
            s_wv + jnp.sum(wv, axis=0),
            s_w2v2 + jnp.sum(wv * wv, axis=0),
        )

    dh = q.shape[-1]
    zeros = jnp.zeros((dh,), jnp.float32)
    s_w, s_w2, s_wv, s_w2v2 = jax.lax.fori_loop(
        0, tiles, tile_step, (jnp.float32(0.0), jnp.float32(0.0), zeros, zeros)
    )
    s_ref[0] = s_w
    s_ref[1] = s_w2
    sv_ref[0, :] = s_wv
    sv_ref[1, :] = s_w2v2


def budget_stats(q, kb, vb, m_ref):
    """Pallas moment kernel.

    Args: q [dh], kb/vb [B0, dh] (B0 multiple of TILE_B), m_ref scalar [1].
    Returns: (scalars [2] = (sum_w, sum_w2), vectors [2, dh]).
    """
    b0, dh = kb.shape
    if b0 % TILE_B != 0:
        raise ValueError(f"base sample {b0} must be a multiple of {TILE_B}")
    kernel = functools.partial(_stats_kernel, tiles=b0 // TILE_B)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((2,), jnp.float32),
            jax.ShapeDtypeStruct((2, dh), jnp.float32),
        ),
        interpret=True,
    )(q, kb, vb, m_ref)
