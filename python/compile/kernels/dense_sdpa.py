"""L1 Pallas kernel: blockwise dense SDPA (Eq. 1) for single-query decode.

The dense baseline the serving engine runs when sparsity is off; also the
numerical oracle at the kernel level. Flash-style: tile the context into
TILE_N-sized VMEM blocks, keep a running (m, l, acc) triple.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 128


def _dense_kernel(q_ref, k_ref, v_ref, o_ref, *, tiles):
    q = q_ref[0, :]

    def tile_step(t, carry):
        m_run, l_run, acc = carry
        kt = k_ref[0, pl.dslice(t * TILE_N, TILE_N), :]
        vt = v_ref[0, pl.dslice(t * TILE_N, TILE_N), :]
        logits = kt @ q
        m_new = jnp.maximum(m_run, jnp.max(logits))
        scale = jnp.where(jnp.isfinite(m_run), jnp.exp(m_run - m_new), 0.0)
        w = jnp.exp(logits - m_new)
        l_new = l_run * scale + jnp.sum(w)
        acc_new = acc * scale + w @ vt
        return m_new, l_new, acc_new

    dh = q.shape[-1]
    init = (-jnp.inf, jnp.float32(0.0), jnp.zeros((dh,), jnp.float32))
    _, l_fin, acc = jax.lax.fori_loop(0, tiles, tile_step, init)
    o_ref[0, :] = acc / jnp.maximum(l_fin, 1e-30)


def dense_sdpa(q, k, v):
    """Pallas dense SDPA: q [H, dh], k/v [H, n, dh] -> [H, dh].

    n must be a multiple of TILE_N (the engine pads the cache bucket).
    """
    h, n, dh = k.shape
    if n % TILE_N != 0:
        raise ValueError(f"context {n} must be a multiple of {TILE_N}")
    kernel = functools.partial(_dense_kernel, tiles=n // TILE_N)
    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, dh), lambda i: (i, 0)),
            pl.BlockSpec((1, n, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, dh), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, dh), jnp.float32),
        interpret=True,
    )(q, k, v)
