"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every kernel in this package has a reference implementation here written
with plain jax.numpy ops only; pytest asserts allclose between the two
across shape/dtype/budget sweeps (see python/tests/).
"""

import jax.numpy as jnp


def sparse_sdpa_ref(q, kg, vg, log_invp, mask):
    """Importance-weighted sparse SDPA (Eq. 3 of the paper), per head.

    Args:
      q:        [H, dh]    query vectors (already scaled by 1/sqrt(dh)).
      kg:       [H, B, dh] gathered keys for the selected indices.
      vg:       [H, B, dh] gathered values.
      log_invp: [H, B]     log(1/p_i) importance weights (0 for p=1).
      mask:     [H, B]     1.0 for valid slots, 0.0 for padding.

    Returns:
      [H, dh] attention outputs.
    """
    logits = jnp.einsum("hbd,hd->hb", kg, q) + log_invp
    logits = jnp.where(mask > 0, logits, -jnp.inf)
    m = jnp.max(logits, axis=-1, keepdims=True)
    # All-masked head guard: exp(-inf - -inf) would be NaN; shift by 0
    # instead (the weights all end up 0 anyway).
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    w = jnp.exp(logits - m)
    denom = jnp.sum(w, axis=-1, keepdims=True)
    out = jnp.einsum("hb,hbd->hd", w, vg)
    return out / jnp.maximum(denom, 1e-30)


def dense_sdpa_ref(q, k, v):
    """Full SDPA (Eq. 1) for a single query per head.

    Args:
      q: [H, dh] scaled queries; k, v: [H, n, dh].
    Returns: [H, dh].
    """
    logits = jnp.einsum("hnd,hd->hn", k, q)
    m = jnp.max(logits, axis=-1, keepdims=True)
    w = jnp.exp(logits - m)
    return jnp.einsum("hn,hnd->hd", w, v) / jnp.sum(w, axis=-1, keepdims=True)


def budget_stats_ref(q, kb, vb, m_ref):
    """Base-sample moments for the verified budget (Algorithm 2's stats).

    Args:
      q:     [dh]      scaled query.
      kb:    [B0, dh]  base-sample keys.
      vb:    [B0, dh]  base-sample values.
      m_ref: []        reference logit for stabilized exponentials.

    Returns:
      (sum_w, sum_w2, sum_wv, sum_w2v2) with shapes ([], [], [dh], [dh]):
      the raw moments rust needs to finish sigma^2, Tr(Sigma), D-hat, N-hat.
    """
    w = jnp.exp(kb @ q - m_ref)  # [B0]
    sum_w = jnp.sum(w)
    sum_w2 = jnp.sum(w * w)
    wv = w[:, None] * vb  # [B0, dh]
    sum_wv = jnp.sum(wv, axis=0)
    sum_w2v2 = jnp.sum(wv * wv, axis=0)
    return sum_w, sum_w2, sum_wv, sum_w2v2
