"""L2: JAX transformer decode-step blocks, built on the L1 Pallas kernels.

These functions define the compute graph the rust engine executes via
PJRT. They are *build-time only*: `aot.py` lowers each block once to HLO
text under artifacts/, and rust never imports python again.

Block decomposition (see DESIGN.md §2): the KV cache lives in rust host
memory so the coordinator can run vAttention index selection over it;
only the *gathered* KV rows cross into the attention artifact. Hence the
decode step is split into

    qkv     : rmsnorm + QKV projection + RoPE            (tiny tensors)
    attn_bB : gathered sparse SDPA (Pallas) + O-proj     (B = budget bucket)
    ffn     : rmsnorm + SwiGLU MLP
    logits  : final rmsnorm + LM head

Weights are runtime *inputs* (uploaded once as device-resident PJRT
buffers), not baked constants — one artifact serves all layers.
"""

import jax.numpy as jnp

from .kernels.sparse_sdpa import sparse_sdpa


# ── Model configuration (mirrors rust/src/model/config.rs) ──────────────

class ModelConfig:
    """Static decode-step shapes. Must match rust::model::ModelConfig."""

    def __init__(self, d_model=256, n_heads=4, n_layers=4, d_ff=704, vocab=2048):
        self.d_model = d_model
        self.n_heads = n_heads
        self.n_layers = n_layers
        self.d_ff = d_ff
        self.vocab = vocab
        assert d_model % n_heads == 0
        self.d_head = d_model // n_heads

    @classmethod
    def tiny(cls):
        """Test-sized model (fast pytest + rust integration tests)."""
        return cls(d_model=64, n_heads=2, n_layers=2, d_ff=128, vocab=256)

    @classmethod
    def small(cls):
        """The end-to-end serving example (~26M params at vocab 8192)."""
        return cls(d_model=512, n_heads=8, n_layers=8, d_ff=1408, vocab=8192)


# ── Blocks ───────────────────────────────────────────────────────────────

def rmsnorm(x, w, eps=1e-5):
    """RMSNorm over the last dim. x [*, D], w [D]."""
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(var + eps)) * w


def apply_rope(x, cos, sin):
    """Rotary embedding for one position. x [H, dh], cos/sin [dh/2]."""
    h, dh = x.shape
    x1 = x[:, : dh // 2]
    x2 = x[:, dh // 2 :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def qkv_block(x, w_ln, wq, wk, wv, cos, sin, cfg: ModelConfig):
    """rmsnorm + QKV projection + RoPE on q and k.

    Args:
      x:    [1, D] residual-stream input.
      w_ln: [D]    norm weight.
      wq/wk/wv: [D, D] projections.
      cos/sin: [dh/2] rotary phases for the current position.
    Returns: q [H, dh] (scaled by 1/sqrt(dh)), k [H, dh], v [H, dh].
    """
    h, dh = cfg.n_heads, cfg.d_head
    xn = rmsnorm(x, w_ln)
    q = (xn @ wq).reshape(h, dh)
    k = (xn @ wk).reshape(h, dh)
    v = (xn @ wv).reshape(h, dh)
    q = apply_rope(q, cos, sin) * (1.0 / jnp.sqrt(jnp.float32(dh)))
    k = apply_rope(k, cos, sin)
    return q, k, v


def attn_block(q, kg, vg, log_invp, mask, wo, cfg: ModelConfig):
    """Gathered sparse attention (Pallas kernel) + output projection.

    Args:
      q:        [H, dh]   scaled, rotated query.
      kg/vg:    [H, B, dh] gathered KV rows (B = budget bucket).
      log_invp: [H, B]    log(1/p) importance weights.
      mask:     [H, B]    validity mask (0 = padding).
      wo:       [D, D]    output projection.
    Returns: [1, D] attention output (pre-residual).
    """
    out = sparse_sdpa(q, kg, vg, log_invp, mask)  # [H, dh]
    return out.reshape(1, cfg.d_model) @ wo


def ffn_block(x, w_ln, w_gate, w_up, w_down):
    """rmsnorm + SwiGLU MLP. x [1, D]; returns [1, D] (pre-residual)."""
    xn = rmsnorm(x, w_ln)
    g = xn @ w_gate  # [1, F]
    u = xn @ w_up    # [1, F]
    act = g * (1.0 / (1.0 + jnp.exp(-g)))  # SiLU
    return (act * u) @ w_down


def logits_block(x, w_ln, w_emb):
    """Final norm + tied LM head. x [1, D], w_emb [V, D] -> [1, V]."""
    xn = rmsnorm(x, w_ln)
    return xn @ w_emb.T
