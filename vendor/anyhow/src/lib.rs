//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The build environment for this repository is fully offline, so the
//! real crates.io `anyhow` cannot be resolved. This shim implements the
//! subset of its API the workspace uses — `Error`, `Result`, the
//! `anyhow!` / `bail!` macros and the `Context` extension trait — with
//! the same semantics: any `std::error::Error` converts into `Error`
//! via `?`, `{:#}` renders the full context chain, and `Error` itself
//! deliberately does *not* implement `std::error::Error` so the blanket
//! `From` impl stays coherent.

use std::error::Error as StdError;
use std::fmt;

/// A dynamic error with a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message (used by `anyhow!`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain, like anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Any std error converts into `Error`, capturing its source chain. This
// is why `Error` must not implement `std::error::Error` itself: the
// reflexive `From<T> for T` impl in std would otherwise overlap.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// results whose error type is a std error.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("missing file"));
    }

    #[test]
    fn alternate_display_shows_context_chain() {
        let e: Result<(), std::io::Error> = Err(io_err());
        let e = e.with_context(|| "loading config").unwrap_err();
        let s = format!("{e:#}");
        assert!(s.starts_with("loading config: "), "{s}");
        assert!(s.contains("missing file"), "{s}");
        // plain display shows only the outermost message
        assert_eq!(format!("{e}"), "loading config");
    }

    #[test]
    fn macros_build_errors() {
        let name = "tiny";
        let e = anyhow!("unknown model '{name}'");
        assert_eq!(format!("{e}"), "unknown model 'tiny'");
        let e = anyhow!("coded {}", 7);
        assert_eq!(format!("{e}"), "coded 7");

        fn bails() -> Result<()> {
            bail!("nope: {}", 3);
        }
        assert_eq!(format!("{}", bails().unwrap_err()), "nope: 3");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
